//! Per-shard block storage and the block-decomposed solve driver.
//!
//! A [`ShardedField`] holds one value block per shard — the shard's
//! *owned box* in local column-major layout — behind one of two backends:
//!
//! - **in-memory**: one `Vec<f64>` per shard, allocated and touched only
//!   by that shard's worker (NUMA-friendly first-touch);
//! - **out-of-core**: one little-endian f64 tile file per shard under a
//!   caller-supplied directory, so grids larger than RAM stream through
//!   bounded buffers (the halo-extended compute box of one shard at a
//!   time).
//!
//! The solve driver ([`solve_blocks`]) advances the same explicit step as
//! `solver::NativeBackend::solve` — `u ← u + α·Ku` over the K-interior,
//! Dirichlet boundary pinned — but over shard blocks with a typed
//! [`HaloMsg`] exchange per step. The result field is **bitwise
//! identical** to the unsharded path: every interior row runs through
//! `engine::kernel::update_row` (the one shared row kernel, same
//! `KernelCfg`) over the same operand values in the same coefficient
//! order, and the update `u + α·Ku` is the same expression; only norm
//! summation order differs (partials combine in shard order), which
//! stays within 1e-9 relative of the flat sums.

use super::{box_strides, box_words, for_each_row, HaloMsg, ShardPlan};
use crate::engine::{kernel, KernelCfg};
use crate::stencil::Stencil;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};
use std::fs::{self, File};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Storage backend selector for a [`ShardedField`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardStorage {
    /// One heap block per shard (the default; current in-RAM behavior).
    InMemory,
    /// One disk tile per shard under `dir` (created on demand; tiles are
    /// removed when the field drops, the directory when it empties).
    OutOfCore { dir: PathBuf },
}

impl ShardStorage {
    /// A fresh process-unique temp directory for out-of-core tiles.
    pub fn temp() -> ShardStorage {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "stencilcache-shard-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        ShardStorage::OutOfCore { dir }
    }
}

enum Backend {
    Mem { blocks: Vec<Vec<f64>> },
    Disk { dir: PathBuf, tag: String },
}

/// A field decomposed into per-shard owned blocks (see module docs).
pub struct ShardedField {
    plan: Arc<ShardPlan>,
    backend: Backend,
}

impl ShardedField {
    pub fn plan(&self) -> &Arc<ShardPlan> {
        &self.plan
    }

    fn path(dir: &std::path::Path, tag: &str, s: usize) -> PathBuf {
        dir.join(format!("{tag}_{s:05}.f64"))
    }

    /// A field with no block data yet (solve ping-pong target: every block
    /// is fully written before it is ever read).
    pub fn empty(plan: Arc<ShardPlan>, storage: &ShardStorage, tag: &str) -> Result<ShardedField> {
        let backend = match storage {
            ShardStorage::InMemory => Backend::Mem { blocks: vec![Vec::new(); plan.num_shards()] },
            ShardStorage::OutOfCore { dir } => {
                fs::create_dir_all(dir)?;
                Backend::Disk { dir: dir.clone(), tag: tag.to_string() }
            }
        };
        Ok(ShardedField { plan, backend })
    }

    /// The deterministic solve input, scattered to shard blocks: zero
    /// everywhere except the K-interior, whose values are drawn in global
    /// natural (dim-0-fastest lexicographic) order from `Rng::new(seed)` —
    /// the exact sequence of `solver::deterministic_field`, so the
    /// decomposed field is bitwise the same no matter the shard grid.
    /// (Restricting a lexicographic visit to any sub-box preserves the
    /// sub-box's own lexicographic order, so per-shard writes are
    /// monotone: each block streams out append-only with zero-fill for
    /// boundary gaps.)
    pub fn deterministic(plan: Arc<ShardPlan>, seed: u64, storage: &ShardStorage, tag: &str) -> Result<ShardedField> {
        let n = plan.num_shards();
        let d = plan.ndim();
        let r = plan.radius() as i64;
        let sizes: Vec<u64> = (0..n).map(|s| box_words(&plan.owned_box(s))).collect();
        let mut sinks: Vec<Sink> = match storage {
            ShardStorage::InMemory => sizes.iter().map(|&w| Sink::Mem(Vec::with_capacity(w as usize))).collect(),
            ShardStorage::OutOfCore { dir } => {
                fs::create_dir_all(dir)?;
                let mut v = Vec::with_capacity(n);
                for s in 0..n {
                    let f = File::create(Self::path(dir, tag, s))?;
                    v.push(Sink::File { w: BufWriter::with_capacity(1 << 16, f), written: 0 });
                }
                v
            }
        };
        // Per-axis lookup: coordinate → (axis-shard index, local coord);
        // per-shard local strides and shard-index strides.
        let ax: Vec<Vec<(usize, u64)>> = (0..d)
            .map(|i| {
                let cuts = plan.axis_cuts(i);
                let mut t = Vec::with_capacity(plan.dims()[i]);
                for x in 0..plan.dims()[i] as i64 {
                    let k = cuts.partition_point(|&c| c <= x) - 1;
                    t.push((k, (x - cuts[k]) as u64));
                }
                t
            })
            .collect();
        let lstrides: Vec<Vec<u64>> = (0..n).map(|s| box_strides(&plan.owned_box(s))).collect();
        let mut gstride = vec![1usize; d];
        for i in 1..d {
            gstride[i] = gstride[i - 1] * plan.shard_grid()[i - 1];
        }
        let has_interior = plan.dims().iter().all(|&nn| nn as i64 >= 2 * r + 1);
        if has_interior {
            let ir: Vec<Range<i64>> = plan.dims().iter().map(|&nn| r..(nn as i64 - r)).collect();
            let mut rng = Rng::new(seed);
            let mut x: Vec<i64> = ir.iter().map(|rg| rg.start).collect();
            'stream: loop {
                for x0 in ir[0].clone() {
                    x[0] = x0;
                    let val = rng.f64() - 0.5;
                    let mut s = 0usize;
                    for i in 0..d {
                        s += ax[i][x[i] as usize].0 * gstride[i];
                    }
                    let mut off = 0u64;
                    for i in 0..d {
                        off += ax[i][x[i] as usize].1 * lstrides[s][i];
                    }
                    sinks[s].push_at(off, val)?;
                }
                let mut i = 1;
                loop {
                    if i == d {
                        break 'stream;
                    }
                    x[i] += 1;
                    if x[i] < ir[i].end {
                        break;
                    }
                    x[i] = ir[i].start;
                    i += 1;
                }
            }
        }
        let backend = match storage {
            ShardStorage::InMemory => {
                let blocks = sinks
                    .into_iter()
                    .zip(&sizes)
                    .map(|(snk, &w)| match snk {
                        Sink::Mem(mut b) => {
                            b.resize(w as usize, 0.0);
                            b
                        }
                        Sink::File { .. } => unreachable!(),
                    })
                    .collect();
                Backend::Mem { blocks }
            }
            ShardStorage::OutOfCore { dir } => {
                for (snk, &w) in sinks.iter_mut().zip(&sizes) {
                    snk.finish(w)?;
                }
                Backend::Disk { dir: dir.clone(), tag: tag.to_string() }
            }
        };
        Ok(ShardedField { plan, backend })
    }

    /// Read a global-coordinate box that lies inside shard `s`'s owned
    /// box, returning its values in column-major order. This is the halo
    /// pack primitive (and, with the full owned box, the block reader).
    pub fn read_box(&self, s: usize, region: &[Range<i64>]) -> Result<Vec<f64>> {
        let owned = self.plan.owned_box(s);
        debug_assert!(
            region.iter().zip(&owned).all(|(rg, o)| rg.start >= o.start && rg.end <= o.end),
            "read_box region {region:?} escapes owned box {owned:?}"
        );
        let ls = box_strides(&owned);
        let total = box_words(region) as usize;
        let mut out = Vec::with_capacity(total);
        match &self.backend {
            Backend::Mem { blocks } => {
                let b = &blocks[s];
                for_each_row(region, |x, len| {
                    let off: usize =
                        x.iter().zip(&owned).zip(&ls).map(|((xi, o), st)| (xi - o.start) as usize * *st as usize).sum();
                    out.extend_from_slice(&b[off..off + len]);
                });
            }
            Backend::Disk { dir, tag } => {
                let mut rows: Vec<(u64, usize)> = Vec::new();
                let mut max_len = 0usize;
                for_each_row(region, |x, len| {
                    let off: u64 = x.iter().zip(&owned).zip(&ls).map(|((xi, o), st)| (xi - o.start) as u64 * st).sum();
                    rows.push((off, len));
                    max_len = max_len.max(len);
                });
                let mut f = File::open(Self::path(dir, tag, s))?;
                let mut bytes = vec![0u8; max_len * 8];
                for (off, len) in rows {
                    f.seek(SeekFrom::Start(off * 8))?;
                    let bb = &mut bytes[..len * 8];
                    f.read_exact(bb)?;
                    for c in bb.chunks_exact(8) {
                        out.push(f64::from_le_bytes(c.try_into().unwrap()));
                    }
                }
            }
        }
        Ok(out)
    }

    /// Replace shard `s`'s block (in-memory backend).
    fn set_block(&mut self, s: usize, data: Vec<f64>) {
        match &mut self.backend {
            Backend::Mem { blocks } => blocks[s] = data,
            Backend::Disk { .. } => unreachable!("disk blocks are written via write_block_shared"),
        }
    }

    /// Write shard `s`'s block through a shared reference — legal for the
    /// disk backend because each worker owns a distinct tile file.
    fn write_block_shared(&self, s: usize, data: &[f64]) -> Result<()> {
        match &self.backend {
            Backend::Mem { .. } => unreachable!("in-memory blocks are returned from the step, not written in place"),
            Backend::Disk { dir, tag } => {
                let f = File::create(Self::path(dir, tag, s))?;
                let mut w = BufWriter::with_capacity(1 << 16, f);
                for v in data {
                    w.write_all(&v.to_le_bytes())?;
                }
                w.flush()?;
                Ok(())
            }
        }
    }

    fn is_disk(&self) -> bool {
        matches!(self.backend, Backend::Disk { .. })
    }

    /// Assemble the full field into the flat column-major layout of an
    /// unpadded grid over `plan.dims()` (tests, experiments, small grids —
    /// materializes |G| words).
    pub fn gather(&self) -> Result<Vec<f64>> {
        let dims = self.plan.dims();
        let mut gstrides = vec![1u64; dims.len()];
        for i in 1..dims.len() {
            gstrides[i] = gstrides[i - 1] * dims[i - 1] as u64;
        }
        let mut out = vec![0.0f64; self.plan.num_points() as usize];
        for s in 0..self.plan.num_shards() {
            let owned = self.plan.owned_box(s);
            let data = self.read_box(s, &owned)?;
            let mut i = 0usize;
            for_each_row(&owned, |x, len| {
                let goff: usize = x.iter().zip(&gstrides).map(|(&xi, &st)| xi as usize * st as usize).sum();
                out[goff..goff + len].copy_from_slice(&data[i..i + len]);
                i += len;
            });
        }
        Ok(out)
    }

    /// Σ v² over the whole field, per-shard partials from the shared
    /// vector reduction ([`kernel::sum_sq`]) combined in shard order.
    pub fn norm_sq(&self) -> Result<f64> {
        let mut acc = 0.0f64;
        for s in 0..self.plan.num_shards() {
            let data = self.read_box(s, &self.plan.owned_box(s))?;
            acc += kernel::sum_sq(&data);
        }
        Ok(acc)
    }
}

impl Drop for ShardedField {
    fn drop(&mut self) {
        if let Backend::Disk { dir, tag } = &self.backend {
            for s in 0..self.plan.num_shards() {
                let _ = fs::remove_file(Self::path(dir, tag, s));
            }
            // succeeds once the last field sharing the directory is gone
            let _ = fs::remove_dir(dir);
        }
    }
}

/// Append-only block writer with zero-fill for skipped (boundary) words.
enum Sink {
    Mem(Vec<f64>),
    File { w: BufWriter<File>, written: u64 },
}

impl Sink {
    fn push_at(&mut self, off: u64, v: f64) -> Result<()> {
        match self {
            Sink::Mem(b) => {
                debug_assert!(off as usize >= b.len(), "scatter offsets must be monotone per shard");
                b.resize(off as usize, 0.0);
                b.push(v);
            }
            Sink::File { w, written } => {
                debug_assert!(off >= *written, "scatter offsets must be monotone per shard");
                const Z: [u8; 8] = [0u8; 8];
                while *written < off {
                    w.write_all(&Z)?;
                    *written += 1;
                }
                w.write_all(&v.to_le_bytes())?;
                *written += 1;
            }
        }
        Ok(())
    }

    fn finish(&mut self, total: u64) -> Result<()> {
        match self {
            Sink::Mem(b) => b.resize(total as usize, 0.0),
            Sink::File { w, written } => {
                const Z: [u8; 8] = [0u8; 8];
                while *written < total {
                    w.write_all(&Z)?;
                    *written += 1;
                }
                w.flush()?;
            }
        }
        Ok(())
    }
}

/// Per-step norms of the block solve (flat squared sums, shard-ordered).
#[derive(Debug, Clone, Copy)]
pub struct StepNorms {
    /// Σ u'² after the step's update.
    pub u2: f64,
    /// Σ (Ku)² before the update.
    pub r2: f64,
    pub micros: u64,
}

/// What the block-decomposed solve returns.
#[derive(Debug)]
pub struct BlockSolveOutcome {
    pub steps: Vec<StepNorms>,
    /// ‖u‖₂ after the last step (input norm when `steps == 0`).
    pub final_norm: f64,
    /// Ghost words carried by [`HaloMsg`]s, summed over shards and
    /// exchange rounds — equals `rounds · plan.halo_words()` with
    /// `rounds = ⌈steps / depth⌉` (the exchange is exact; one full
    /// `depth·r`-deep exchange per superstep, `steps` rounds classic).
    pub halo_words_loaded: u64,
    /// Number of [`HaloMsg`]s exchanged, summed over shards and rounds.
    pub halo_exchanges: u64,
    /// Ghost-zone stencil points recomputed redundantly by deep sweeps —
    /// work a classic per-step exchange would not do, counted separately
    /// from the exchanged words so the measured-vs-PEM ladder stays
    /// honest. Always 0 for depth-1 plans.
    pub halo_redundant_words: u64,
}

struct ShardStepOut {
    block: Option<Vec<f64>>,
    u2: f64,
    r2: f64,
    halo_words: u64,
    halo_msgs: u64,
}

/// Per-shard result of one `kk`-step deep-halo superstep.
struct ShardSuperOut {
    block: Option<Vec<f64>>,
    /// Per sweep-step `(Σ u'², Σ (Ku)²)` partials over *owned* points, in
    /// the exact add order of the classic per-step sweep.
    norms: Vec<(f64, f64)>,
    halo_words: u64,
    halo_msgs: u64,
    /// Stencil applications beyond what `kk` classic steps would compute.
    redundant: u64,
}

/// Copy a column-major `region` payload into the halo-extended buffer.
fn unpack_region(buf: &mut [f64], ext: &[Range<i64>], estrides: &[u64], region: &[Range<i64>], data: &[f64]) {
    let mut i = 0usize;
    for_each_row(region, |x, len| {
        let off: usize = x.iter().zip(ext).zip(estrides).map(|((xi, e), st)| (xi - e.start) as usize * *st as usize).sum();
        buf[off..off + len].copy_from_slice(&data[i..i + len]);
        i += len;
    });
}

/// Advance one shard one step: assemble the halo-extended buffer from the
/// shard's own old block plus one [`HaloMsg`] per source, then sweep the
/// owned box in local natural order computing `u + α·Ku` at K-interior
/// points (boundary points copy through — the Dirichlet condition).
///
/// Each row's K-interior run goes through [`kernel::update_row`] with the
/// shard's *running* norm accumulators, so the nonzero `u2`/`r2` addends
/// land in exactly the order the pre-kernel scalar sweep produced —
/// `tests/shard.rs` pins the grid-of-1 step norms bitwise against a flat
/// scalar reference.
#[allow(clippy::too_many_arguments)]
fn step_shard(
    plan: &ShardPlan,
    stencil: &Stencil,
    alpha: f64,
    cur: &ShardedField,
    next: &ShardedField,
    s: usize,
    interior: Option<&[Range<i64>]>,
    cfg: &KernelCfg,
) -> Result<ShardStepOut> {
    let d = plan.ndim();
    let ext = plan.halo_box(s);
    let estrides = box_strides(&ext);
    let mut buf = vec![0.0f64; box_words(&ext) as usize];
    let owned = plan.owned_box(s);
    let own_data = cur.read_box(s, &owned)?;
    unpack_region(&mut buf, &ext, &estrides, &owned, &own_data);
    drop(own_data);
    let (mut halo_words, mut halo_msgs) = (0u64, 0u64);
    for (src, region) in plan.sources_for(s) {
        let data = cur.read_box(src, &region)?;
        let m = HaloMsg { src, dst: s, region, data };
        halo_words += m.words();
        halo_msgs += 1;
        unpack_region(&mut buf, &ext, &estrides, &m.region, &m.data);
    }
    let coeffs = stencil.coeffs();
    let deltas: Vec<i64> =
        stencil.offsets().iter().map(|k| k.iter().zip(&estrides).map(|(&ki, &st)| ki * st as i64).sum()).collect();
    let mut out = Vec::with_capacity(box_words(&owned) as usize);
    // running (Σ v², Σ (Ku)²) accumulators for the whole shard sweep —
    // update_row continues them in increasing-point order rather than
    // returning per-row partials, preserving the scalar add sequence
    let mut acc = (0.0f64, 0.0f64);
    let mut x: Vec<i64> = owned.iter().map(|rg| rg.start).collect();
    'sweep: loop {
        // buffer offset of the row's first owned element (x[0] stays at
        // owned[0].start; only higher coordinates advance)
        let mut base: i64 =
            x.iter().zip(&ext).zip(&estrides).map(|((xi, e), st)| (xi - e.start) * *st as i64).sum();
        // the dim-0 run of K-interior points within this row, empty when a
        // higher coordinate sits on the boundary shell
        let hi_ok = interior.map_or(false, |ir| (1..d).all(|i| x[i] >= ir[i].start && x[i] < ir[i].end));
        let (ilo, ihi) = match interior {
            Some(ir) if hi_ok => (ir[0].start.max(owned[0].start), ir[0].end.min(owned[0].end)),
            _ => (owned[0].start, owned[0].start),
        };
        // a shard whose dim-0 extent sits entirely in the boundary shell
        // yields an inverted clamp — normalize to the empty run
        let (ilo, ihi) = if ilo < ihi { (ilo, ihi) } else { (owned[0].start, owned[0].start) };
        // boundary prefix copies through (Dirichlet), counted in Σ v²
        for _ in owned[0].start..ilo {
            let v = buf[base as usize];
            acc.0 += v * v;
            out.push(v);
            base += 1;
        }
        // K-interior run through the shared row kernel
        let run = (ihi - ilo) as usize;
        if run > 0 {
            let start = out.len();
            out.resize(start + run, 0.0);
            // SAFETY: `out` was just resized to hold `run` words at
            // `start`, does not alias `buf`, and every fold at
            // `base + j + delta` stays inside the halo-extended buffer
            // because interior points carry a full radius of ghosts.
            unsafe {
                kernel::update_row(
                    coeffs,
                    &deltas,
                    &buf,
                    base,
                    alpha,
                    run,
                    0,
                    run,
                    out.as_mut_ptr().add(start),
                    &mut acc,
                    cfg,
                );
            }
            base += run as i64;
        }
        // boundary suffix copies through
        for _ in ihi..owned[0].end {
            let v = buf[base as usize];
            acc.0 += v * v;
            out.push(v);
            base += 1;
        }
        let mut i = 1;
        loop {
            if i == d {
                break 'sweep;
            }
            x[i] += 1;
            if x[i] < owned[i].end {
                break;
            }
            x[i] = owned[i].start;
            i += 1;
        }
    }
    let (u2, r2) = acc;
    if next.is_disk() {
        next.write_block_shared(s, &out)?;
        Ok(ShardStepOut { block: None, u2, r2, halo_words, halo_msgs })
    } else {
        Ok(ShardStepOut { block: Some(out), u2, r2, halo_words, halo_msgs })
    }
}

/// Superstep scheduling unit for the in-memory dependency graph: packs
/// deliver ghost regions, computes run the moment their inbox fills.
enum SuperTask {
    /// Read the shard's outbound ghost regions from its old block and
    /// deliver one [`HaloMsg`] per destination (no dependencies).
    Pack(usize),
    /// Every inbound halo landed: run the shard's deep sweep.
    Compute(usize),
}

/// Advance one shard `kk` steps from a *single* deep-halo exchange.
///
/// The `depth·r`-deep halo buffer is assembled once — the shard's own old
/// block plus one [`HaloMsg`] per source (pre-delivered by pack tasks on
/// the in-memory graph path, or pulled straight from the immutable `cur`
/// field on the out-of-core wave path) — then a trapezoidal sweep runs
/// `kk` steps ping-ponging two halo-box-sized buffers: sweep-step `s`
/// rewrites the owned box grown by `(kk − s)·r` (clipped to the grid), so
/// every operand of step `s + 1` is already updated and step `kk` lands
/// exactly on the owned box.
///
/// Bitwise contract (pinned by `tests/shard.rs`): every K-interior point
/// goes through [`kernel::update_row`], whose per-point values are
/// position-independent; boundary-shell points copy through; and norms
/// accumulate **only at owned points**, in exactly the scalar add order
/// of [`step_shard`] — so both the extracted block and the per-step norm
/// partials are bitwise equal to `kk` classic exchanged steps.
#[allow(clippy::too_many_arguments)]
fn superstep_shard(
    plan: &ShardPlan,
    stencil: &Stencil,
    alpha: f64,
    cur: &ShardedField,
    next: &ShardedField,
    s: usize,
    kk: usize,
    interior: &[Range<i64>],
    msgs: Option<Vec<HaloMsg>>,
    cfg: &KernelCfg,
) -> Result<ShardSuperOut> {
    let d = plan.ndim();
    let ext = plan.halo_box(s);
    let estrides = box_strides(&ext);
    let ext_len = box_words(&ext) as usize;
    let mut a = vec![0.0f64; ext_len];
    let owned = plan.owned_box(s);
    let own_data = cur.read_box(s, &owned)?;
    unpack_region(&mut a, &ext, &estrides, &owned, &own_data);
    drop(own_data);
    let (mut halo_words, mut halo_msgs) = (0u64, 0u64);
    match msgs {
        Some(list) => {
            for m in &list {
                debug_assert_eq!(m.dst, s);
                halo_words += m.words();
                halo_msgs += 1;
                unpack_region(&mut a, &ext, &estrides, &m.region, &m.data);
            }
        }
        None => {
            for (src, region) in plan.sources_for(s) {
                let data = cur.read_box(src, &region)?;
                let m = HaloMsg { src, dst: s, region, data };
                halo_words += m.words();
                halo_msgs += 1;
                unpack_region(&mut a, &ext, &estrides, &m.region, &m.data);
            }
        }
    }
    let mut b = vec![0.0f64; ext_len];
    let coeffs = stencil.coeffs();
    let deltas: Vec<i64> =
        stencil.offsets().iter().map(|k| k.iter().zip(&estrides).map(|(&ki, &st)| ki * st as i64).sum()).collect();
    // |owned ∩ interior| — what one classic exchanged step computes here
    let classic_points: u64 = box_words(
        &owned.iter().zip(interior).map(|(o, i)| o.start.max(i.start)..o.end.min(i.end)).collect::<Vec<_>>(),
    );
    let mut norms = Vec::with_capacity(kk);
    let mut redundant = 0u64;
    let mut flip = false; // false: a → b, true: b → a
    for step in 1..=kk {
        let bx = plan.sweep_box(s, kk, step);
        let (src, dst): (&[f64], *mut f64) = if flip { (&b, a.as_mut_ptr()) } else { (&a, b.as_mut_ptr()) };
        let mut acc = (0.0f64, 0.0f64);
        let mut computed = 0u64;
        let mut x: Vec<i64> = bx.iter().map(|rg| rg.start).collect();
        'sweep: loop {
            let mut base: i64 =
                x.iter().zip(&ext).zip(&estrides).map(|((xi, e), st)| (xi - e.start) * *st as i64).sum();
            let hi_int = (1..d).all(|i| x[i] >= interior[i].start && x[i] < interior[i].end);
            let hi_own = (1..d).all(|i| x[i] >= owned[i].start && x[i] < owned[i].end);
            // the dim-0 K-interior run of this row (empty off the shell)
            let (ilo, ihi) = if hi_int {
                let lo = interior[0].start.max(bx[0].start);
                let hi = interior[0].end.min(bx[0].end);
                if lo < hi {
                    (lo, hi)
                } else {
                    (bx[0].start, bx[0].start)
                }
            } else {
                (bx[0].start, bx[0].start)
            };
            // prefix copy-through (boundary shell or pure ghost rind);
            // Σ v² continues only at owned points, like the classic sweep
            for x0 in bx[0].start..ilo {
                let v = src[base as usize];
                // SAFETY: base indexes inside the ext buffer (x ∈ bx ⊆ ext).
                unsafe { dst.add(base as usize).write(v) };
                if hi_own && x0 >= owned[0].start && x0 < owned[0].end {
                    acc.0 += v * v;
                }
                base += 1;
            }
            let run = (ihi - ilo) as usize;
            if run > 0 {
                // norm window: the owned sub-run (empty on off-owned rows)
                let (nlo, nhi) = if hi_own {
                    let lo = owned[0].start.max(ilo);
                    let hi = owned[0].end.min(ihi);
                    if lo < hi {
                        (lo, hi)
                    } else {
                        (ilo, ilo)
                    }
                } else {
                    (ilo, ilo)
                };
                // SAFETY: dst spans the ext buffer and never aliases src
                // (ping-pong pair); every fold at `base + j + delta` stays
                // inside the buffer because step-`s` operands lie one
                // radius inside the previous sweep box, which was fully
                // (re)written — or assembled, for step 1 — beforehand.
                unsafe {
                    kernel::update_row(
                        coeffs,
                        &deltas,
                        src,
                        base,
                        alpha,
                        run,
                        (nlo - ilo) as usize,
                        (nhi - ilo) as usize,
                        dst.add(base as usize),
                        &mut acc,
                        cfg,
                    );
                }
                computed += run as u64;
                base += run as i64;
            }
            // suffix copy-through
            for x0 in ihi..bx[0].end {
                let v = src[base as usize];
                // SAFETY: as above — base stays inside the ext buffer.
                unsafe { dst.add(base as usize).write(v) };
                if hi_own && x0 >= owned[0].start && x0 < owned[0].end {
                    acc.0 += v * v;
                }
                base += 1;
            }
            let mut i = 1;
            loop {
                if i == d {
                    break 'sweep;
                }
                x[i] += 1;
                if x[i] < bx[i].end {
                    break;
                }
                x[i] = bx[i].start;
                i += 1;
            }
        }
        norms.push(acc);
        redundant += computed - classic_points;
        flip = !flip;
    }
    // extract the owned block from the final ping-pong buffer
    let fin: &[f64] = if flip { &b } else { &a };
    let mut out = Vec::with_capacity(box_words(&owned) as usize);
    for_each_row(&owned, |x, len| {
        let off: usize =
            x.iter().zip(&ext).zip(&estrides).map(|((xi, e), st)| (xi - e.start) as usize * *st as usize).sum();
        out.extend_from_slice(&fin[off..off + len]);
    });
    if next.is_disk() {
        next.write_block_shared(s, &out)?;
        Ok(ShardSuperOut { block: None, norms, halo_words, halo_msgs, redundant })
    } else {
        Ok(ShardSuperOut { block: Some(out), norms, halo_words, halo_msgs, redundant })
    }
}

/// Run `steps` explicit steps `u ← u + α·Ku` over the decomposition,
/// returning the outcome **and** the final field (tests compare it
/// bitwise against the unsharded path). See [`solve_blocks`] for the
/// drop-the-field convenience wrapper.
///
/// Under the out-of-core backend with a RAM budget, the per-step fan-out
/// is throttled to `budget / peak_working_words` concurrent shards (the
/// halo-extended buffer plus the written block per in-flight shard), and
/// the call fails fast if even a single shard's working set exceeds the
/// budget — the planner's grid refinement should have prevented that.
#[allow(clippy::too_many_arguments)]
pub fn solve_blocks_with_field(
    plan: &Arc<ShardPlan>,
    stencil: &Stencil,
    alpha: f64,
    steps: usize,
    seed: u64,
    storage: &ShardStorage,
    pool: &ThreadPool,
    ram_budget_words: Option<u64>,
) -> Result<(BlockSolveOutcome, ShardedField)> {
    solve_blocks_with_field_cfg(
        plan,
        stencil,
        alpha,
        steps,
        seed,
        storage,
        pool,
        ram_budget_words,
        &KernelCfg::default(),
    )
}

/// [`solve_blocks_with_field`] with explicit kernel knobs — the same
/// `KernelCfg` the unsharded `NativeBackend` runs, so decomposed-vs-classic
/// bitwise equality holds mode-for-mode.
#[allow(clippy::too_many_arguments)]
pub fn solve_blocks_with_field_cfg(
    plan: &Arc<ShardPlan>,
    stencil: &Stencil,
    alpha: f64,
    steps: usize,
    seed: u64,
    storage: &ShardStorage,
    pool: &ThreadPool,
    ram_budget_words: Option<u64>,
    cfg: &KernelCfg,
) -> Result<(BlockSolveOutcome, ShardedField)> {
    assert_eq!(plan.ndim(), stencil.ndim(), "plan/stencil arity mismatch");
    assert_eq!(plan.radius(), stencil.radius(), "ghost width must equal the stencil radius");
    // A deep plan only pays off when every dim has a nonempty interior
    // (≥ 2r+1); below that the superstep path cannot run and the classic
    // loop would exchange depth·r-deep halos every step, breaking the
    // rounds = ⌈steps/depth⌉ invariant documented on BlockSolveOutcome.
    // Degrade such plans to an equivalent depth-1 plan up front — the
    // planner never emits one, but direct ShardPlan::with_depth callers
    // (benches, CLI overrides) can.
    let has_interior = plan.dims().iter().all(|&nn| nn >= 2 * plan.radius() + 1);
    let clamped: Arc<ShardPlan>;
    let plan: &Arc<ShardPlan> = if plan.depth() > 1 && !has_interior {
        clamped = Arc::new(ShardPlan::with_depth(plan.dims(), plan.shard_grid(), plan.radius(), 1));
        &clamped
    } else {
        plan
    };
    let n = plan.num_shards();
    let conc = match (storage, ram_budget_words) {
        (ShardStorage::OutOfCore { .. }, Some(b)) => {
            let per_shard = plan.peak_working_words().max(1);
            if per_shard > b {
                bail!(
                    "RAM budget of {b} words cannot hold one shard's working set ({per_shard} words); \
                     a finer shard grid than {:?} is required",
                    plan.shard_grid()
                );
            }
            ((b / per_shard) as usize).clamp(1, n)
        }
        _ => n,
    };
    let mut cur = ShardedField::deterministic(plan.clone(), seed, storage, "a")?;
    let mut next = ShardedField::empty(plan.clone(), storage, "b")?;
    let interior: Option<Vec<Range<i64>>> = if has_interior {
        let r = plan.radius();
        Some(plan.dims().iter().map(|&nn| r as i64..(nn - r) as i64).collect())
    } else {
        None
    };
    let ids: Vec<usize> = (0..n).collect();
    let mut step_norms = Vec::with_capacity(steps);
    let (mut hw, mut hx, mut hr) = (0u64, 0u64, 0u64);
    if plan.depth() > 1 && interior.is_some() {
        // ------- deep-halo superstep path (parallel temporal blocking) --
        // One full depth·r exchange per superstep of up to `depth` sweep
        // steps: exchange rounds drop to ⌈steps/depth⌉ and
        // halo_words_loaded to rounds · plan.halo_words() exactly (tail
        // supersteps still exchange the full deep halo — the accounting
        // invariant the bench gate pins).
        let ir = interior.as_deref().unwrap();
        let k = plan.depth();
        let mut done = 0usize;
        while done < steps {
            let kk = k.min(steps - done);
            let t0 = Instant::now();
            let supers: Vec<ShardSuperOut> = if cur.is_disk() {
                // out-of-core: chunked waves under the RAM budget; halos
                // are pulled straight from `cur`, which stays immutable
                // for the whole superstep
                let mut slots: Vec<Option<ShardSuperOut>> = (0..n).map(|_| None).collect();
                for wave in ids.chunks(conc.max(1)) {
                    let results = pool.scope_map(wave.len(), |w| {
                        superstep_shard(plan, stencil, alpha, &cur, &next, wave[w], kk, ir, None, cfg)
                    });
                    for (w, res) in results.into_iter().enumerate() {
                        slots[wave[w]] = Some(res?);
                    }
                }
                slots.into_iter().map(|o| o.expect("missing shard result")).collect()
            } else {
                // in-memory: dependency-driven pack/compute graph on the
                // pool — no wave barrier; a shard's deep sweep launches
                // the moment its own neighbors' buffers land, not when
                // the slowest shard of a wave finishes
                let srcs: Vec<Vec<(usize, Vec<Range<i64>>)>> =
                    ids.iter().map(|&sh| plan.sources_for(sh)).collect();
                let mut outbound: Vec<Vec<(usize, Vec<Range<i64>>)>> = vec![Vec::new(); n];
                for (dst, list) in srcs.iter().enumerate() {
                    for (src, region) in list {
                        outbound[*src].push((dst, region.clone()));
                    }
                }
                let pending: Vec<AtomicUsize> = srcs.iter().map(|l| AtomicUsize::new(l.len())).collect();
                let inbox: Vec<Mutex<Vec<HaloMsg>>> = (0..n).map(|_| Mutex::new(Vec::new())).collect();
                let slots: Vec<Mutex<Option<Result<ShardSuperOut>>>> = (0..n).map(|_| Mutex::new(None)).collect();
                let mut seed_tasks: Vec<SuperTask> =
                    (0..n).filter(|&sh| !outbound[sh].is_empty()).map(SuperTask::Pack).collect();
                seed_tasks.extend((0..n).filter(|&sh| srcs[sh].is_empty()).map(SuperTask::Compute));
                pool.scope_tasks(seed_tasks, |task, sink| match task {
                    SuperTask::Pack(src) => {
                        for (dst, region) in &outbound[src] {
                            let data =
                                cur.read_box(src, region).expect("in-memory halo pack cannot fail");
                            inbox[*dst].lock().unwrap().push(HaloMsg {
                                src,
                                dst: *dst,
                                region: region.clone(),
                                data,
                            });
                            if pending[*dst].fetch_sub(1, Ordering::SeqCst) == 1 {
                                sink.push(SuperTask::Compute(*dst));
                            }
                        }
                    }
                    SuperTask::Compute(sh) => {
                        let msgs = std::mem::take(&mut *inbox[sh].lock().unwrap());
                        let res = superstep_shard(plan, stencil, alpha, &cur, &next, sh, kk, ir, Some(msgs), cfg);
                        *slots[sh].lock().unwrap() = Some(res);
                    }
                });
                let mut out = Vec::with_capacity(n);
                for m in slots {
                    out.push(m.into_inner().unwrap().expect("missing shard result")?);
                }
                out
            };
            // combine per-step partials in shard order — the same add
            // sequence as the classic per-step loop, so norms are bitwise
            // independent of the scheduling
            let mut per_step = vec![(0.0f64, 0.0f64); kk];
            for (sh, r) in supers.into_iter().enumerate() {
                if let Some(bk) = r.block {
                    next.set_block(sh, bk);
                }
                for (t, &(u2, r2)) in r.norms.iter().enumerate() {
                    per_step[t].0 += u2;
                    per_step[t].1 += r2;
                }
                hw += r.halo_words;
                hx += r.halo_msgs;
                hr += r.redundant;
            }
            let micros = (t0.elapsed().as_micros() as u64 / kk as u64).max(1);
            for &(u2, r2) in &per_step {
                step_norms.push(StepNorms { u2, r2, micros });
            }
            std::mem::swap(&mut cur, &mut next);
            done += kk;
        }
    } else {
        // ----------------- classic one-exchange-per-step path ----------
        for _ in 0..steps {
            let t0 = Instant::now();
            let (mut u2, mut r2) = (0.0f64, 0.0f64);
            for wave in ids.chunks(conc.max(1)) {
                let results = pool.scope_map(wave.len(), |w| {
                    step_shard(plan, stencil, alpha, &cur, &next, wave[w], interior.as_deref(), cfg)
                });
                for (w, res) in results.into_iter().enumerate() {
                    let r = res?;
                    if let Some(b) = r.block {
                        next.set_block(wave[w], b);
                    }
                    // partials combine in shard order — independent of the
                    // wave size, so norms don't depend on the RAM budget
                    u2 += r.u2;
                    r2 += r.r2;
                    hw += r.halo_words;
                    hx += r.halo_msgs;
                }
            }
            step_norms.push(StepNorms { u2, r2, micros: t0.elapsed().as_micros() as u64 });
            std::mem::swap(&mut cur, &mut next);
        }
    }
    let final_norm = match step_norms.last() {
        Some(sn) => sn.u2.sqrt(),
        None => cur.norm_sq()?.sqrt(),
    };
    let outcome = BlockSolveOutcome {
        steps: step_norms,
        final_norm,
        halo_words_loaded: hw,
        halo_exchanges: hx,
        halo_redundant_words: hr,
    };
    Ok((outcome, cur))
}

/// [`solve_blocks_with_field`] without the field (the coordinator path).
/// For the out-of-core backend this also removes the tile directory.
#[allow(clippy::too_many_arguments)]
pub fn solve_blocks(
    plan: &Arc<ShardPlan>,
    stencil: &Stencil,
    alpha: f64,
    steps: usize,
    seed: u64,
    storage: &ShardStorage,
    pool: &ThreadPool,
    ram_budget_words: Option<u64>,
) -> Result<BlockSolveOutcome> {
    solve_blocks_cfg(plan, stencil, alpha, steps, seed, storage, pool, ram_budget_words, &KernelCfg::default())
}

/// [`solve_blocks`] with explicit kernel knobs (the coordinator path).
#[allow(clippy::too_many_arguments)]
pub fn solve_blocks_cfg(
    plan: &Arc<ShardPlan>,
    stencil: &Stencil,
    alpha: f64,
    steps: usize,
    seed: u64,
    storage: &ShardStorage,
    pool: &ThreadPool,
    ram_budget_words: Option<u64>,
    cfg: &KernelCfg,
) -> Result<BlockSolveOutcome> {
    let (outcome, field) =
        solve_blocks_with_field_cfg(plan, stencil, alpha, steps, seed, storage, pool, ram_budget_words, cfg)?;
    drop(field);
    if let ShardStorage::OutOfCore { dir } = storage {
        let _ = fs::remove_dir(dir);
    }
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridDesc;
    use crate::solver::deterministic_field;

    fn pool() -> ThreadPool {
        ThreadPool::new(3)
    }

    #[test]
    fn deterministic_scatter_matches_flat_field() {
        for grid in [vec![1usize, 1], vec![2, 3], vec![4, 1]] {
            let plan = Arc::new(ShardPlan::new(&[11, 9], &grid, 1));
            let f = ShardedField::deterministic(plan, 0xBEEF, &ShardStorage::InMemory, "a").unwrap();
            let flat = deterministic_field(&GridDesc::new(&[11, 9]), 1, 0xBEEF);
            assert_eq!(f.gather().unwrap(), flat, "grid {grid:?}");
        }
    }

    #[test]
    fn out_of_core_scatter_matches_in_memory() {
        let plan = Arc::new(ShardPlan::new(&[10, 8, 6], &[2, 2, 1], 1));
        let mem = ShardedField::deterministic(plan.clone(), 7, &ShardStorage::InMemory, "a").unwrap();
        let storage = ShardStorage::temp();
        let disk = ShardedField::deterministic(plan, 7, &storage, "a").unwrap();
        assert_eq!(mem.gather().unwrap(), disk.gather().unwrap());
        assert_eq!(mem.norm_sq().unwrap(), disk.norm_sq().unwrap());
        drop(disk);
        if let ShardStorage::OutOfCore { dir } = &storage {
            assert!(!dir.exists(), "dropping the last field must remove the tile dir");
        }
    }

    #[test]
    fn read_box_returns_column_major_region() {
        let plan = Arc::new(ShardPlan::new(&[6, 4], &[1, 1], 1));
        let f = ShardedField::deterministic(plan, 3, &ShardStorage::InMemory, "a").unwrap();
        let all = f.gather().unwrap();
        let region = vec![1..4i64, 1..3i64];
        let got = f.read_box(0, &region).unwrap();
        let mut want = Vec::new();
        for x1 in 1..3usize {
            for x0 in 1..4usize {
                want.push(all[x1 * 6 + x0]);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn zero_step_solve_returns_input_norm() {
        let plan = Arc::new(ShardPlan::new(&[9, 9], &[3, 1], 2));
        let s = Stencil::star(2, 2);
        let p = pool();
        let (out, _f) =
            solve_blocks_with_field(&plan, &s, 0.05, 0, 42, &ShardStorage::InMemory, &p, None).unwrap();
        let flat = deterministic_field(&GridDesc::new(&[9, 9]), 2, 42);
        let want = flat.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!((out.final_norm - want).abs() < 1e-12 * (1.0 + want));
        assert_eq!(out.halo_exchanges, 0);
    }

    #[test]
    fn budget_smaller_than_one_shard_fails_fast() {
        let plan = Arc::new(ShardPlan::new(&[16, 16], &[2, 2], 1));
        let s = Stencil::star(2, 1);
        let p = pool();
        let storage = ShardStorage::temp();
        let err = solve_blocks(&plan, &s, 0.1, 1, 1, &storage, &p, Some(8)).unwrap_err();
        assert!(err.to_string().contains("RAM budget"), "{err}");
    }
}
