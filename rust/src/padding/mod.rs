//! §6: unfavorable array sizes and the padding advisor.
//!
//! A grid is **unfavorable** when its interference lattice contains a very
//! short vector — shorter than the stencil diameter divided by the cache
//! associativity. Then distinct points inside one stencil application
//! collide in the cache and *no* traversal order can avoid the misses; the
//! fix is padding the array so the lattice loses its short vector. The
//! paper's empirical characterization: unfavorable grids lie near the
//! hyperbolae `n_1·n_2 = k·S/2` (Figure 5).
//!
//! The advisor searches small pads of the first d−1 dimensions (the last
//! extent does not enter the lattice: Eq 8 uses strides n_1…n_{d−1} only)
//! and picks, among pads whose lattice clears the short-vector bar, the one
//! minimizing memory overhead and then basis eccentricity ("the shortest
//! vector ... not too short, though short enough to minimize the number of
//! pencils").

use crate::cache::CacheParams;
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::stencil::Stencil;

/// Outcome of a padding search.
#[derive(Debug, Clone)]
pub struct PaddingAdvice {
    /// Chosen per-dimension pads (last dim always 0).
    pub pad: Vec<usize>,
    /// The padded storage dims.
    pub storage_dims: Vec<usize>,
    /// L1 length of the shortest lattice vector after padding (within the
    /// searched horizon).
    pub min_l1: Option<i64>,
    /// Reduced-basis eccentricity after padding.
    pub eccentricity: f64,
    /// Extra words per array, as a fraction of the unpadded size.
    pub overhead: f64,
    /// Whether the advised layout clears the unfavorability bar.
    pub favorable: bool,
}

/// The §6 unfavorability bar: the stencil diameter — a lattice vector
/// shorter than this forces conflicts inside single stencil applications
/// that no traversal can avoid. (§4's *upper-bound validity* needs only
/// diameter/associativity; empirically the diameter is the right
/// classification bar — see Figure 4's n1 = 90 spike on the 2-way R10000.)
pub fn short_vector_bar(stencil: &Stencil, _cache: &CacheParams) -> i64 {
    stencil.diameter() as i64
}

/// Is this grid unfavorable for the given stencil and cache (§6 criterion)?
pub fn is_unfavorable(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams) -> bool {
    let lat = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
    lat.is_unfavorable(stencil.diameter() as i64)
}

/// The paper's empirical hyperbola criterion (Figure 5 caption): the
/// product of the first two storage dims is within `tol` (relative) of a
/// multiple of S/2. Only meaningful for d ≥ 2.
pub fn near_half_cache_multiple(grid: &GridDesc, cache: &CacheParams, tol: f64) -> bool {
    let dims = grid.storage_dims();
    if dims.len() < 2 {
        return false;
    }
    let prod = (dims[0] * dims[1]) as f64;
    let half_s = cache.lattice_modulus() as f64 / 2.0;
    let k = (prod / half_s).round();
    if k < 1.0 {
        return false;
    }
    (prod - k * half_s).abs() / half_s <= tol
}

/// Search pads `0..=max_pad` for the first d−1 dims; return the best
/// advice per the ordering described in the module docs.
pub fn advise(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams, max_pad: usize) -> PaddingAdvice {
    let d = grid.ndim();
    let dims = grid.dims();
    let bar = short_vector_bar(stencil, cache);
    let modulus = cache.lattice_modulus();
    let base_words: f64 = dims.iter().map(|&n| n as f64).product();

    let mut best: Option<(PaddingAdvice, (u8, u64, u64))> = None; // (advice, sort key)
    let mut pad = vec![0usize; d];
    // odometer over pads of dims 0..d-1 (last dim fixed at 0)
    loop {
        let storage: Vec<usize> = dims.iter().zip(&pad).map(|(&n, &p)| n + p).collect();
        let lat = InterferenceLattice::new(&storage, modulus);
        let min_l1 = lat.min_l1(bar.max(8));
        // Advice is stricter than classification: borderline layouts with
        // min_l1 == diameter (e.g. 46×91's (2,−2,1)) measurably thrash, so
        // the advisor demands strictly longer shortest vectors.
        let favorable = min_l1.map(|m| m > bar).unwrap_or(true);
        let ecc = lat.eccentricity();
        let padded_words: f64 = storage.iter().map(|&n| n as f64).product();
        let overhead = padded_words / base_words - 1.0;
        // Sort key: favorable first, then overhead (scaled), then ecc.
        let key = (
            u8::from(!favorable),
            (overhead * 1e6) as u64,
            (ecc * 1e3) as u64,
        );
        let advice = PaddingAdvice {
            pad: pad.clone(),
            storage_dims: storage,
            min_l1,
            eccentricity: ecc,
            overhead,
            favorable,
        };
        if best.as_ref().map(|(_, bk)| key < *bk).unwrap_or(true) {
            best = Some((advice, key));
        }
        // advance odometer (dims 0..d-1); early-exit once a zero-overhead
        // favorable pad is found (pad = 0 everywhere).
        if let Some((a, _)) = &best {
            if a.favorable && a.overhead == 0.0 {
                break;
            }
        }
        let pad_dims = d - 1; // last dim never padded (lattice-irrelevant)
        let mut i = 0;
        loop {
            if i == pad_dims {
                return best.unwrap().0;
            }
            pad[i] += 1;
            if pad[i] <= max_pad {
                break;
            }
            pad[i] = 0;
            i += 1;
        }
    }
    best.unwrap().0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r10k() -> CacheParams {
        CacheParams::r10000()
    }

    #[test]
    fn bar_for_13pt_star_on_r10000() {
        // diameter 2r+1 = 5 for the 13-point star.
        assert_eq!(short_vector_bar(&Stencil::star13(), &r10k()), 5);
        assert_eq!(short_vector_bar(&Stencil::star(3, 1), &r10k()), 3);
    }

    #[test]
    fn paper_grids_classified() {
        let s13 = Stencil::star13();
        // The Figure 4 spikes.
        assert!(is_unfavorable(&GridDesc::new(&[45, 91, 100]), &s13, &r10k()));
        assert!(is_unfavorable(&GridDesc::new(&[90, 91, 100]), &s13, &r10k()));
        // A neighbor that the figure shows as quiet.
        assert!(!is_unfavorable(&GridDesc::new(&[47, 91, 100]), &s13, &r10k()));
    }

    #[test]
    fn hyperbola_criterion_matches_spikes() {
        let c = r10k();
        // 45·91 = 4095 ≈ 2·(4096/2): k=2 multiple, within 0.1%.
        assert!(near_half_cache_multiple(&GridDesc::new(&[45, 91, 100]), &c, 0.01));
        // 90·91 = 8190 ≈ 4·2048.
        assert!(near_half_cache_multiple(&GridDesc::new(&[90, 91, 100]), &c, 0.01));
        // 67·89 = 5963: nearest multiple 3·2048 = 6144, off by 3% > 1%.
        assert!(!near_half_cache_multiple(&GridDesc::new(&[67, 89, 100]), &c, 0.01));
    }

    #[test]
    fn advise_fixes_unfavorable_grid() {
        let g = GridDesc::new(&[45, 91, 100]);
        let adv = advise(&g, &Stencil::star13(), &r10k(), 8);
        assert!(adv.favorable, "{adv:?}");
        assert!(adv.overhead > 0.0, "45×91 needs actual padding");
        assert!(adv.overhead < 0.2, "padding should be cheap: {adv:?}");
        // verify the advised storage really is favorable
        let padded = GridDesc::with_padding(g.dims(), &adv.pad);
        assert!(!is_unfavorable(&padded, &Stencil::star13(), &r10k()));
        // last dim untouched
        assert_eq!(adv.pad[2], 0);
    }

    #[test]
    fn advise_keeps_favorable_grid_unpadded() {
        let g = GridDesc::new(&[67, 89, 100]);
        let adv = advise(&g, &Stencil::star13(), &r10k(), 8);
        assert!(adv.favorable);
        assert_eq!(adv.pad, vec![0, 0, 0]);
        assert_eq!(adv.overhead, 0.0);
    }

    #[test]
    fn advise_2d() {
        // 2-D grid with n1 = S/2 — on the k=1 hyperbola, very unfavorable.
        let c = CacheParams::new(2, 128, 4); // S = 1024
        let g = GridDesc::new(&[512, 40]);
        let s = Stencil::star(2, 2);
        assert!(is_unfavorable(&g, &s, &c));
        let adv = advise(&g, &s, &c, 8);
        assert!(adv.favorable, "{adv:?}");
        let padded = GridDesc::with_padding(g.dims(), &adv.pad);
        assert!(!is_unfavorable(&padded, &s, &c));
    }

    #[test]
    fn property_advised_grids_always_clear_bar_or_best_effort() {
        use crate::util::proptest::{forall, DimsGen};
        let c = CacheParams::new(2, 64, 2); // S = 256
        let s = Stencil::star(3, 1);
        let bar = short_vector_bar(&s, &c);
        forall(77, 20, &DimsGen { d: 3, lo: 10, hi: 90 }, |dims| {
            let g = GridDesc::new(dims);
            let adv = advise(&g, &s, &c, 6);
            // structural invariants of any advice
            let pads_ok = adv.pad.iter().all(|&p| p <= 6) && adv.pad[2] == 0;
            // a favorable verdict must be backed by the actual lattice
            let verdict_ok = !adv.favorable
                || InterferenceLattice::new(&adv.storage_dims, 256)
                    .min_l1(bar)
                    .map(|m| m >= bar)
                    .unwrap_or(true);
            pads_ok && verdict_ok
        });
    }
}
