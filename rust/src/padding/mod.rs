//! §6: unfavorable array sizes and the padding advisor.
//!
//! A grid is **unfavorable** when its interference lattice contains a very
//! short vector — shorter than the stencil diameter divided by the cache
//! associativity. Then distinct points inside one stencil application
//! collide in the cache and *no* traversal order can avoid the misses; the
//! fix is padding the array so the lattice loses its short vector. The
//! paper's empirical characterization: unfavorable grids lie near the
//! hyperbolae `n_1·n_2 = k·S/2` (Figure 5).
//!
//! The advisor searches small pads of the first d−1 dimensions (the last
//! extent does not enter the lattice: Eq 8 uses strides n_1…n_{d−1} only)
//! and picks, among pads whose lattice clears the short-vector bar, the one
//! minimizing memory overhead and then basis eccentricity ("the shortest
//! vector ... not too short, though short enough to minimize the number of
//! pencils"), and finally — among otherwise-equal pads — the one whose
//! dim-0 storage extent is closest to a cache-line multiple, so pencil
//! base offsets stay line-aligned for the vector kernel's unit-stride row
//! loads (`engine::kernel`). Alignment is deliberately the *last*
//! objective: it never spends overhead the lattice criterion didn't
//! already require.
//!
//! With a hierarchical [`MachineModel`] the same criterion applies **per
//! level**: the TLB induces a *page interference lattice* (modulus = the
//! TLB's word reach, [`MachineModel::page_modulus`]) and
//! [`advise_machine`] demands the pad clear the short-vector bar on every
//! lattice the machine exposes — a grid can be TLB-unfavorable while
//! L1-favorable whenever the two moduli are not nested.

use crate::cache::{CacheParams, MachineModel};
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::stencil::Stencil;

/// Outcome of a padding search.
#[derive(Debug, Clone)]
pub struct PaddingAdvice {
    /// Chosen per-dimension pads (last dim always 0).
    pub pad: Vec<usize>,
    /// The padded storage dims.
    pub storage_dims: Vec<usize>,
    /// L1 length of the shortest lattice vector after padding (within the
    /// searched horizon).
    pub min_l1: Option<i64>,
    /// Reduced-basis eccentricity after padding.
    pub eccentricity: f64,
    /// Extra words per array, as a fraction of the unpadded size.
    pub overhead: f64,
    /// Whether the advised layout clears the unfavorability bar.
    pub favorable: bool,
}

/// The §6 unfavorability bar: the stencil diameter — a lattice vector
/// shorter than this forces conflicts inside single stencil applications
/// that no traversal can avoid. (§4's *upper-bound validity* needs only
/// diameter/associativity; empirically the diameter is the right
/// classification bar — see Figure 4's n1 = 90 spike on the 2-way R10000.)
pub fn short_vector_bar(stencil: &Stencil, _cache: &CacheParams) -> i64 {
    stencil.diameter() as i64
}

/// Is this grid unfavorable for the given stencil and cache (§6 criterion)?
pub fn is_unfavorable(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams) -> bool {
    is_unfavorable_mod(grid, stencil, cache.lattice_modulus())
}

/// The §6 criterion against an explicit lattice modulus — used for the
/// page interference lattice (`modulus =`
/// [`MachineModel::page_modulus`]) as well as the cache-line one.
pub fn is_unfavorable_mod(grid: &GridDesc, stencil: &Stencil, modulus: usize) -> bool {
    let lat = InterferenceLattice::new(grid.storage_dims(), modulus);
    lat.is_unfavorable(stencil.diameter() as i64)
}

/// The paper's empirical hyperbola criterion (Figure 5 caption): the
/// product of the first two storage dims is within `tol` (relative) of a
/// multiple of S/2. Only meaningful for d ≥ 2.
pub fn near_half_cache_multiple(grid: &GridDesc, cache: &CacheParams, tol: f64) -> bool {
    let dims = grid.storage_dims();
    if dims.len() < 2 {
        return false;
    }
    let prod = (dims[0] * dims[1]) as f64;
    let half_s = cache.lattice_modulus() as f64 / 2.0;
    let k = (prod / half_s).round();
    if k < 1.0 {
        return false;
    }
    (prod - k * half_s).abs() / half_s <= tol
}

/// Search pads `0..=max_pad` for the first d−1 dims; return the best
/// advice per the ordering described in the module docs.
pub fn advise(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams, max_pad: usize) -> PaddingAdvice {
    advise_moduli(grid, &[cache.lattice_modulus()], short_vector_bar(stencil, cache), max_pad, cache.line_words)
}

/// [`advise`] against every lattice a machine exposes: the cache-line
/// lattice plus, when the machine has a TLB, the page interference
/// lattice. A pad is favorable only when it clears the short-vector bar
/// on **all** of them; the reported `min_l1`/`eccentricity` describe the
/// cache-line lattice (the one the traversal machinery consumes).
pub fn advise_machine(grid: &GridDesc, stencil: &Stencil, machine: &MachineModel, max_pad: usize) -> PaddingAdvice {
    let mut moduli = vec![machine.l1.lattice_modulus()];
    if let Some(m) = machine.page_modulus() {
        moduli.push(m);
    }
    advise_moduli(grid, &moduli, short_vector_bar(stencil, &machine.l1), max_pad, machine.l1.line_words)
}

/// Does `storage`'s lattice mod `modulus` clear the advisor's strict bar
/// (shortest vector within the search horizon strictly longer than the
/// stencil diameter)?
fn clears_bar(storage: &[usize], modulus: usize, bar: i64) -> bool {
    InterferenceLattice::new(storage, modulus).min_l1(bar.max(8)).map(|m| m > bar).unwrap_or(true)
}

/// The pad search over an explicit modulus list (first entry = the
/// cache-line lattice, which supplies the reported diagnostics),
/// short-vector bar (the stencil diameter), and the L1 line size in words
/// (the kernel-alignment tie-break).
fn advise_moduli(grid: &GridDesc, moduli: &[usize], bar: i64, max_pad: usize, line_words: usize) -> PaddingAdvice {
    assert!(!moduli.is_empty());
    let d = grid.ndim();
    let dims = grid.dims();
    let base_words: f64 = dims.iter().map(|&n| n as f64).product();

    let mut best: Option<(PaddingAdvice, (u8, u64, u64, u64))> = None; // (advice, sort key)
    let mut pad = vec![0usize; d];
    // odometer over pads of dims 0..d-1 (last dim fixed at 0)
    loop {
        let storage: Vec<usize> = dims.iter().zip(&pad).map(|(&n, &p)| n + p).collect();
        let lat = InterferenceLattice::new(&storage, moduli[0]);
        let min_l1 = lat.min_l1(bar.max(8));
        // Advice is stricter than classification: borderline layouts with
        // min_l1 == diameter (e.g. 46×91's (2,−2,1)) measurably thrash, so
        // the advisor demands strictly longer shortest vectors — on every
        // lattice the machine exposes. (The primary lattice reuses the
        // min_l1 already computed above instead of re-reducing.)
        let primary_ok = min_l1.map(|m| m > bar).unwrap_or(true);
        let favorable = primary_ok && moduli[1..].iter().all(|&m| clears_bar(&storage, m, bar));
        let ecc = lat.eccentricity();
        let padded_words: f64 = storage.iter().map(|&n| n as f64).product();
        let overhead = padded_words / base_words - 1.0;
        // Sort key: favorable first, then overhead (scaled), then ecc,
        // then pencil-base misalignment — how far the dim-0 storage
        // extent (the stride between consecutive row bases) sits from a
        // cache-line multiple. A line-multiple extent keeps every row's
        // vector loads on one line boundary pattern and lets the kernel's
        // prefetch land whole lines (DESIGN.md §2.11).
        let key = (
            u8::from(!favorable),
            (overhead * 1e6) as u64,
            (ecc * 1e3) as u64,
            (storage[0] % line_words.max(1)) as u64,
        );
        let advice = PaddingAdvice {
            pad: pad.clone(),
            storage_dims: storage,
            min_l1,
            eccentricity: ecc,
            overhead,
            favorable,
        };
        if best.as_ref().map(|(_, bk)| key < *bk).unwrap_or(true) {
            best = Some((advice, key));
        }
        // advance odometer (dims 0..d-1); early-exit once a zero-overhead
        // favorable pad is found (pad = 0 everywhere).
        if let Some((a, _)) = &best {
            if a.favorable && a.overhead == 0.0 {
                break;
            }
        }
        let pad_dims = d - 1; // last dim never padded (lattice-irrelevant)
        let mut i = 0;
        loop {
            if i == pad_dims {
                return best.unwrap().0;
            }
            pad[i] += 1;
            if pad[i] <= max_pad {
                break;
            }
            pad[i] = 0;
            i += 1;
        }
    }
    best.unwrap().0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r10k() -> CacheParams {
        CacheParams::r10000()
    }

    #[test]
    fn bar_for_13pt_star_on_r10000() {
        // diameter 2r+1 = 5 for the 13-point star.
        assert_eq!(short_vector_bar(&Stencil::star13(), &r10k()), 5);
        assert_eq!(short_vector_bar(&Stencil::star(3, 1), &r10k()), 3);
    }

    #[test]
    fn paper_grids_classified() {
        let s13 = Stencil::star13();
        // The Figure 4 spikes.
        assert!(is_unfavorable(&GridDesc::new(&[45, 91, 100]), &s13, &r10k()));
        assert!(is_unfavorable(&GridDesc::new(&[90, 91, 100]), &s13, &r10k()));
        // A neighbor that the figure shows as quiet.
        assert!(!is_unfavorable(&GridDesc::new(&[47, 91, 100]), &s13, &r10k()));
    }

    #[test]
    fn hyperbola_criterion_matches_spikes() {
        let c = r10k();
        // 45·91 = 4095 ≈ 2·(4096/2): k=2 multiple, within 0.1%.
        assert!(near_half_cache_multiple(&GridDesc::new(&[45, 91, 100]), &c, 0.01));
        // 90·91 = 8190 ≈ 4·2048.
        assert!(near_half_cache_multiple(&GridDesc::new(&[90, 91, 100]), &c, 0.01));
        // 67·89 = 5963: nearest multiple 3·2048 = 6144, off by 3% > 1%.
        assert!(!near_half_cache_multiple(&GridDesc::new(&[67, 89, 100]), &c, 0.01));
    }

    #[test]
    fn advise_fixes_unfavorable_grid() {
        let g = GridDesc::new(&[45, 91, 100]);
        let adv = advise(&g, &Stencil::star13(), &r10k(), 8);
        assert!(adv.favorable, "{adv:?}");
        assert!(adv.overhead > 0.0, "45×91 needs actual padding");
        assert!(adv.overhead < 0.2, "padding should be cheap: {adv:?}");
        // verify the advised storage really is favorable
        let padded = GridDesc::with_padding(g.dims(), &adv.pad);
        assert!(!is_unfavorable(&padded, &Stencil::star13(), &r10k()));
        // last dim untouched
        assert_eq!(adv.pad[2], 0);
    }

    #[test]
    fn advise_keeps_favorable_grid_unpadded() {
        let g = GridDesc::new(&[67, 89, 100]);
        let adv = advise(&g, &Stencil::star13(), &r10k(), 8);
        assert!(adv.favorable);
        assert_eq!(adv.pad, vec![0, 0, 0]);
        assert_eq!(adv.overhead, 0.0);
    }

    #[test]
    fn advise_2d() {
        // 2-D grid with n1 = S/2 — on the k=1 hyperbola, very unfavorable.
        let c = CacheParams::new(2, 128, 4); // S = 1024
        let g = GridDesc::new(&[512, 40]);
        let s = Stencil::star(2, 2);
        assert!(is_unfavorable(&g, &s, &c));
        let adv = advise(&g, &s, &c, 8);
        assert!(adv.favorable, "{adv:?}");
        let padded = GridDesc::with_padding(g.dims(), &adv.pad);
        assert!(!is_unfavorable(&padded, &s, &c));
    }

    #[test]
    fn tlb_unfavorable_while_l1_favorable_and_advisor_resolves_both() {
        use crate::cache::{Latency, MachineModel, TlbParams};
        // A TLB span (36·512 = 18432) that is not a multiple of the L1
        // modulus (4096): the page lattice can then hold a short vector
        // the cache-line lattice lacks. 95×97 has n1·n2 = 9215, so
        // (2,0,2) lies in the page lattice (2·9215 + 2 = span) while the
        // shortest vector mod 4096 has L1 norm > 5.
        let machine = MachineModel {
            name: "r10000+tlb36",
            l1: CacheParams::r10000(),
            l2: None,
            tlb: Some(TlbParams { entries: 36, page_words: 512 }),
            latency: Latency::r10000(),
        };
        let g = GridDesc::new(&[95, 97, 40]);
        let s = Stencil::star13();
        assert!(!is_unfavorable(&g, &s, &machine.l1), "grid must be L1-favorable");
        assert!(is_unfavorable_mod(&g, &s, machine.page_modulus().unwrap()), "grid must be TLB-unfavorable");
        let adv = advise_machine(&g, &s, &machine, 8);
        assert!(adv.favorable, "{adv:?}");
        let padded = GridDesc::with_padding(g.dims(), &adv.pad);
        assert!(!is_unfavorable(&padded, &s, &machine.l1));
        assert!(!is_unfavorable_mod(&padded, &s, machine.page_modulus().unwrap()));
    }

    #[test]
    fn advise_machine_single_level_equals_advise() {
        use crate::cache::MachineModel;
        // With no TLB the machine search must degenerate to the classic
        // single-lattice advisor, pad for pad.
        for dims in [[45usize, 91, 100], [67, 89, 100], [90, 91, 100]] {
            let g = GridDesc::new(&dims);
            let a = advise(&g, &Stencil::star13(), &r10k(), 8);
            let b = advise_machine(&g, &Stencil::star13(), &MachineModel::r10000(), 8);
            assert_eq!(a.pad, b.pad, "{dims:?}");
            assert_eq!(a.favorable, b.favorable);
            assert_eq!(a.min_l1, b.min_l1);
        }
    }

    #[test]
    fn alignment_tie_break_is_last_and_matches_brute_force() {
        // Replicate the advisor's full sort key — (favorable, overhead,
        // eccentricity, dim-0 misalignment) — over the whole pad lattice
        // in the advisor's own visit order and assert advise() returns the
        // lexicographic argmin. This pins that pencil-base alignment
        // participates in the objective, and only *after* the §6 lattice
        // criteria (it can never buy alignment with extra overhead).
        let c = r10k(); // 4-word lines
        let s = Stencil::star13();
        let bar = short_vector_bar(&s, &c);
        for dims in [[45usize, 91, 100], [90, 91, 100], [512, 40, 10]] {
            let base: f64 = dims.iter().map(|&n| n as f64).product();
            let mut best: Option<(u8, u64, u64, u64)> = None;
            let mut best_pad = vec![0usize; 3];
            for p1 in 0..=8usize {
                for p0 in 0..=8usize {
                    let storage = vec![dims[0] + p0, dims[1] + p1, dims[2]];
                    let lat = InterferenceLattice::new(&storage, c.lattice_modulus());
                    let fav = lat.min_l1(bar.max(8)).map(|m| m > bar).unwrap_or(true);
                    let words: f64 = storage.iter().map(|&n| n as f64).product();
                    let key = (
                        u8::from(!fav),
                        ((words / base - 1.0) * 1e6) as u64,
                        (lat.eccentricity() * 1e3) as u64,
                        (storage[0] % c.line_words) as u64,
                    );
                    if best.map(|b| key < b).unwrap_or(true) {
                        best = Some(key);
                        best_pad = vec![p0, p1, 0];
                    }
                }
            }
            let adv = advise(&GridDesc::new(&dims), &s, &c, 8);
            assert_eq!(adv.pad, best_pad, "{dims:?}");
        }
    }

    #[test]
    fn property_advised_grids_always_clear_bar_or_best_effort() {
        use crate::util::proptest::{forall, DimsGen};
        let c = CacheParams::new(2, 64, 2); // S = 256
        let s = Stencil::star(3, 1);
        let bar = short_vector_bar(&s, &c);
        forall(77, 20, &DimsGen { d: 3, lo: 10, hi: 90 }, |dims| {
            let g = GridDesc::new(dims);
            let adv = advise(&g, &s, &c, 6);
            // structural invariants of any advice
            let pads_ok = adv.pad.iter().all(|&p| p <= 6) && adv.pad[2] == 0;
            // a favorable verdict must be backed by the actual lattice
            let verdict_ok = !adv.favorable
                || InterferenceLattice::new(&adv.storage_dims, 256)
                    .min_l1(bar)
                    .map(|m| m >= bar)
                    .unwrap_or(true);
            pads_ok && verdict_ok
        });
    }
}
