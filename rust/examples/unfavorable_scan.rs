//! Reproduce the §6 unfavorable-grid phenomenology interactively: scan
//! (n1, n2) space, print the short-vector map (Figure 5B) and verify the
//! hyperbola law n1·n2 ≈ k·S/2.
//!
//! Run with: `cargo run --release --example unfavorable_scan -- [--lo 40 --hi 100]`

use stencilcache::cache::CacheParams;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]).unwrap_or_default();
    let lo = args.get_usize("lo", 40).unwrap_or(40);
    let hi = args.get_usize("hi", 100).unwrap_or(100);
    let cache = CacheParams::r10000();
    let s = cache.lattice_modulus();

    println!("short-vector map (L1 < 8), n1,n2 ∈ [{lo},{hi}), S = {s}; ■ = unfavorable\n");
    let mut on_hyperbola = 0usize;
    let mut short_total = 0usize;
    for n2 in (lo..hi).rev() {
        let mut row = String::with_capacity(hi - lo + 8);
        for n1 in lo..hi {
            let lat = InterferenceLattice::new(&[n1, n2, 50], s);
            let short = lat.min_l1(7).is_some();
            if short {
                short_total += 1;
                let prod = (n1 * n2) as f64;
                let k = (prod / (s as f64 / 2.0)).round();
                if k >= 1.0 && (prod - k * s as f64 / 2.0).abs() / (s as f64 / 2.0) <= 0.02 {
                    on_hyperbola += 1;
                }
            }
            row.push(if short { '■' } else { '·' });
        }
        println!("{n2:>4} {row}");
    }
    println!(
        "\n{short_total} unfavorable grids; {on_hyperbola} lie within 2% of a n1·n2 = k·S/2 hyperbola ({:.0}%)",
        100.0 * on_hyperbola as f64 / short_total.max(1) as f64
    );
    println!("(the paper: 'arrays with unfavorable size are those whose z-slices are");
    println!(" (close to) multiples of half the cache size')");
}
