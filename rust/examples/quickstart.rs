//! Quickstart: the 60-second tour of the public API.
//!
//! 1. describe a grid and a stencil;
//! 2. inspect its interference lattice (is it unfavorable?);
//! 3. compare traversal orders in the cache simulator;
//! 4. ask the padding advisor for a fix.
//!
//! Run with: `cargo run --release --example quickstart`

use stencilcache::cache::{CacheParams, CacheSim};
use stencilcache::engine;
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::lattice::InterferenceLattice;
use stencilcache::padding;
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use stencilcache::tuner;

fn main() {
    // The paper's measurement platform: MIPS R10000, 32 KB 2-way L1,
    // S = 4096 double-precision words.
    let cache = CacheParams::r10000();
    // A grid right on the paper's Figure-4 spike: 45×91×100.
    let grid = GridDesc::new(&[45, 91, 100]);
    let stencil = Stencil::star13();

    println!("grid {:?}, stencil |K|={} r={}", grid.dims(), stencil.size(), stencil.radius());

    // --- lattice analysis -------------------------------------------------
    let lat = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
    println!("interference lattice (Eq 8/9): det = {} (= S)", lat.determinant());
    println!("  reduced basis: {:?}", lat.reduced_basis());
    println!("  shortest vector: {:?} (|v|₂ = {:.2})", lat.shortest(), lat.shortest_len());
    println!("  unfavorable for this stencil? {}", lat.is_unfavorable(stencil.diameter() as i64));

    // --- measure traversals ----------------------------------------------
    let layout = MultiArrayLayout::paper_offsets(&grid, 1, cache.size_words());
    let mut measure = |name: &str, order: &traversal::Order| {
        let mut sim = CacheSim::new(cache);
        let rep = engine::simulate(order, &layout, &stencil, &mut sim);
        println!("  {name:<28} misses/pt = {:.3}  u-loads/pt = {:.3}", rep.misses_per_point(), rep.u_loads_per_point());
    };
    println!("\nsimulated misses on (2,512,4):");
    measure("natural (compiler)", &traversal::natural(&grid, 2));
    let (auto_order, chosen) = tuner::auto_fitting_order(&grid, &stencil, &cache);
    measure(&format!("cache fitting [{}]", chosen.name()), &auto_order);

    // --- padding advice ----------------------------------------------------
    let advice = padding::advise(&grid, &stencil, &cache, 8);
    println!(
        "\npadding advisor: pad {:?} → storage {:?} (overhead {:.1}%)",
        advice.pad,
        advice.storage_dims,
        advice.overhead * 100.0
    );
    let padded = GridDesc::with_padding(grid.dims(), &advice.pad);
    let playout = MultiArrayLayout::paper_offsets(&padded, 1, cache.size_words());
    let (porder, pchosen) = tuner::auto_fitting_order(&padded, &stencil, &cache);
    let mut sim = CacheSim::new(cache);
    let rep = engine::simulate(&porder, &playout, &stencil, &mut sim);
    println!("  after padding [{}]: misses/pt = {:.3}", pchosen.name(), rep.misses_per_point());
}
