//! Padding advisor walkthrough: scan a band of grid sizes, flag the
//! unfavorable ones (§6), and print the advised padding with its cost.
//!
//! Run with: `cargo run --release --example padding_advisor -- [--n2 91]`

use stencilcache::cache::CacheParams;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::padding;
use stencilcache::report::Table;
use stencilcache::stencil::Stencil;
use stencilcache::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]).unwrap_or_default();
    let n2 = args.get_usize("n2", 91).unwrap_or(91);
    let cache = CacheParams::r10000();
    let stencil = Stencil::star13();

    let mut table = Table::new(
        &format!("padding advice for n1×{n2}×100 grids, cache (2,512,4)"),
        &["n1", "min L1 vec", "unfavorable", "advised pad", "storage", "overhead %", "min L1 after"],
    );
    for n1 in 40..100 {
        let grid = GridDesc::new(&[n1, n2, 100]);
        let lat = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
        let unfav = padding::is_unfavorable(&grid, &stencil, &cache);
        if !unfav {
            continue; // only report the problem cases
        }
        let advice = padding::advise(&grid, &stencil, &cache, 8);
        table.add_row(vec![
            n1.to_string(),
            lat.min_l1(8).map(|m| m.to_string()).unwrap_or_else(|| ">8".into()),
            "YES".into(),
            format!("{:?}", advice.pad),
            format!("{:?}", advice.storage_dims),
            format!("{:.2}", advice.overhead * 100.0),
            advice.min_l1.map(|m| m.to_string()).unwrap_or_else(|| ">bar".into()),
        ]);
    }
    println!("{}", table.to_text());
    println!("(grids not listed are already favorable; padding the first two dims");
    println!(" moves n1·n2 off the k·S/2 hyperbolae — see Figure 5 of the paper)");
}
