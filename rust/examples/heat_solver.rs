//! **End-to-end driver** (the mandated full-stack workload): solve the 3-D
//! heat equation `u_t = ∇²u` with zero Dirichlet boundaries on a 64³ grid
//! by explicit (damped-Jacobi) iteration through the coordinator's solve
//! path — on whichever numeric backend is available:
//!
//! - **pjrt** (needs `make artifacts` + the `pjrt` feature): L1 Pallas
//!   13-point-star kernel → L2 fused JAX step+norms graph → L3 PJRT CPU
//!   runtime; python is nowhere at runtime.
//! - **native** (always available): the pure-Rust engine sweep over the
//!   planner-chosen traversal, sharded across the worker pool, with
//!   per-step residual/L2 reductions.
//!
//! The residual curve is logged per step; the run is recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run with: `cargo run --release --example heat_solver -- [--n 64 --steps 300]`

use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::runtime::RuntimeService;
use stencilcache::util::cli::Args;

fn main() {
    let args = Args::from_env(&[]).unwrap_or_default();
    let n = args.get_usize("n", 64).unwrap_or(64);
    let steps = args.get_usize("steps", 300).unwrap_or(300);

    // Keep the service alive for the whole run (it owns the executor
    // thread); fall back to the native backend when it cannot start.
    // Backend choice is per-request (artifact shape match); report what is
    // *available* (including why PJRT is not), and read the metrics
    // afterwards for what actually ran.
    let svc = match RuntimeService::start(None) {
        Ok(s) => Some(s),
        Err(e) => {
            println!("runtime: native numeric backend (pjrt unavailable: {e})");
            None
        }
    };
    let coord = match &svc {
        Some(s) => {
            println!(
                "runtime: pjrt available ({}) — native fallback per request  |  grid {n}³  |  {steps} heat steps",
                s.handle().platform()
            );
            Coordinator::with_runtime(PlannerConfig::default(), s.handle())
        }
        None => Coordinator::analysis_only(PlannerConfig::default()),
    };

    let t0 = std::time::Instant::now();
    let resp = coord
        .submit(&StencilRequest {
            dims: vec![n, n, n],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps },
        })
        .unwrap_or_else(|e| {
            eprintln!("solve failed: {e}");
            std::process::exit(1);
        });
    let wall = t0.elapsed();

    println!("\n step      ||u||₂      ||Ku||₂   µs/step");
    let stride = (steps / 25).max(1);
    for s in resp.solve_log.iter().step_by(stride) {
        println!("{:>5}  {:>10.4}  {:>10.4}  {:>8}", s.step, s.u_norm, s.residual_norm, s.micros);
    }
    if let (Some(first), Some(last)) = (resp.solve_log.first(), resp.solve_log.last()) {
        println!(
            "\nenergy decay: ||u|| {:.4} → {:.4}  ({:.1}% dissipated)",
            first.u_norm,
            last.u_norm,
            100.0 * (1.0 - last.u_norm / first.u_norm)
        );
        assert!(last.u_norm < first.u_norm, "explicit heat step must dissipate energy");
        assert!(last.residual_norm.is_finite());
    }
    let pts = (n * n * n * steps) as f64;
    println!(
        "wall: {:.2} s  |  {:.1} Mpoint·step/s end-to-end  |  {:.2} ms/step",
        wall.as_secs_f64(),
        pts / wall.as_secs_f64() / 1e6,
        wall.as_secs_f64() * 1e3 / steps as f64
    );
    println!("\ncoordinator metrics:\n{}", coord.metrics_json());
}
