//! PJRT runtime bench: artifact execution latency/throughput through the
//! full L3 path (literal marshalling + execute + tuple fetch) and via the
//! actor service thread. Needs `make artifacts`.

use stencilcache::coordinator::deterministic_input;
use stencilcache::runtime::{Runtime, RuntimeService};
use stencilcache::util::bench::Bencher;

fn main() {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime bench (no artifacts): {e}");
            return;
        }
    };
    let mut b = Bencher::from_env();

    for n in [16usize, 32, 64] {
        let u = deterministic_input(&[n, n, n], 42);
        let name = format!("star13_{n}");
        if rt.manifest().find(&name).is_none() {
            continue;
        }
        let _ = rt.execute(&name, &[&u]).unwrap(); // compile outside timing
        let pts = (n * n * n) as f64;
        b.bench_items(&format!("pjrt/star13_{n}"), pts, || rt.execute(&name, &[&u]).unwrap());
    }

    // fused step+norms (the solver hot call)
    let u = deterministic_input(&[64, 64, 64], 43);
    if rt.manifest().find("step_norms_64").is_some() {
        let _ = rt.execute("step_norms_64", &[&u]).unwrap();
        b.bench_items("pjrt/step_norms_64", 64.0 * 64.0 * 64.0, || rt.execute("step_norms_64", &[&u]).unwrap());
    }
    // in-graph 10-step sweep vs 10 round trips
    if rt.manifest().find("jacobi_sweep_64x10").is_some() {
        let _ = rt.execute("jacobi_sweep_64x10", &[&u]).unwrap();
        b.bench_items("pjrt/jacobi_sweep_64x10 (10 steps fused)", 10.0 * 64.0 * 64.0 * 64.0, || {
            rt.execute("jacobi_sweep_64x10", &[&u]).unwrap()
        });
    }
    drop(rt);

    // the actor-service path (adds channel hops)
    if let Ok(svc) = RuntimeService::start(None) {
        let h = svc.handle();
        let u16 = deterministic_input(&[16, 16, 16], 44);
        let _ = h.execute("star13_16", &[&u16]).unwrap();
        b.bench_items("pjrt/service_star13_16", 16.0 * 16.0 * 16.0, || h.execute("star13_16", &[&u16]).unwrap());
    }
}
