//! End-to-end bench: the coordinator serving a mixed workload (plans,
//! analyses, PJRT executes) through batching + thread pool — the headline
//! L3 throughput number for §Perf — plus the sharded-vs-sequential
//! streaming analysis scaling check.

use stencilcache::cache::{CacheParams, CacheSim, MachineModel};
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::engine;
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::runtime::RuntimeService;
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use stencilcache::util::bench::Bencher;
use stencilcache::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bencher::from_env();

    // sharded streaming analysis: same 96³ star13 job, sequential vs fanned
    // out over the pool — wall time should scale with cores.
    let grid = GridDesc::new(&[96, 96, 96]);
    let cache = CacheParams::r10000();
    let stencil = Stencil::star13();
    let layout = MultiArrayLayout::paper_offsets(&grid, 1, cache.size_words());
    let accesses = grid.interior_points(2) as f64 * 14.0;
    let t = traversal::natural_stream(&grid, 2);
    b.bench_items("analyze_96^3/sequential", accesses, || {
        let mut sim = CacheSim::new(cache);
        engine::simulate(&t, &layout, &stencil, &mut sim)
    });
    let pool = ThreadPool::with_default_parallelism();
    let shards = pool.workers() * 2;
    b.bench_items("analyze_96^3/sharded", accesses, || {
        engine::simulate_sharded(&t, &layout, &stencil, &MachineModel::l1_only(cache), &pool, shards)
    });

    // analysis-only serving (no PJRT dependency). Memoization is disabled
    // so this stays a *simulation throughput* number — the memoized
    // serving path is bench_serving's subject.
    let mut coord = Coordinator::analysis_only(PlannerConfig::default());
    coord.configure_memo(None);
    let reqs: Vec<StencilRequest> = (0..16)
        .map(|i| {
            let n = [16usize, 20, 24][i % 3];
            StencilRequest::analyze(&[n, n, n])
        })
        .collect();
    b.bench_items("coordinator/serve_16_analyses", 16.0, || coord.serve(&reqs));

    // plan-only latency (pure lattice math)
    let plan_req = StencilRequest {
        dims: vec![45, 91, 100],
        stencil: StencilSpec::Star13,
        rhs_arrays: 1,
        kind: JobKind::Plan,
    };
    b.bench("coordinator/plan_45x91x100", || coord.submit(&plan_req).unwrap());

    // with runtime: solve steps end to end
    if let Ok(svc) = RuntimeService::start(None) {
        let c2 = Coordinator::with_runtime(PlannerConfig::default(), svc.handle());
        let solve = StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 5 },
        };
        let _ = c2.submit(&solve).unwrap(); // warm the executable cache
        b.bench_items("coordinator/solve_16^3_x5steps", 5.0 * 4096.0, || c2.submit(&solve).unwrap());
    } else {
        eprintln!("(skipping PJRT e2e bench — run `make artifacts`)");
    }
}
