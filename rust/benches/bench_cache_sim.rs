//! Microbench: the cache-simulator hot path (the L3 bottleneck — FIG5A
//! pushes ~2·10⁹ accesses through `CacheSim::access`). §Perf tracks the
//! accesses/s number here.

use stencilcache::cache::{CacheParams, CacheSim};
use stencilcache::util::bench::Bencher;
use stencilcache::util::rng::Rng;

fn main() {
    let mut b = Bencher::from_env();
    let n = 100_000u64;

    // Sequential sweep: MRU-hit fast path.
    let mut sim = CacheSim::new(CacheParams::r10000());
    b.bench_items("cache_sim/sequential_100k", n as f64, || {
        for a in 0..n {
            sim.access(a % 1_000_000);
        }
    });

    // Strided column walk: the conflict-heavy pattern of natural-order 3-D.
    let mut sim2 = CacheSim::new(CacheParams::r10000());
    b.bench_items("cache_sim/strided_100k", n as f64, || {
        let mut a = 0u64;
        for _ in 0..n {
            a = (a + 4004) % 4_000_000;
            sim2.access(a);
        }
    });

    // Random access: worst-case branchy path.
    let mut sim3 = CacheSim::new(CacheParams::r10000());
    let mut rng = Rng::new(7);
    let addrs: Vec<u64> = (0..n).map(|_| rng.below(4_000_000)).collect();
    b.bench_items("cache_sim/random_100k", n as f64, || {
        for &a in &addrs {
            sim3.access(a);
        }
    });

    // Fully associative (one big set).
    let mut sim4 = CacheSim::new(CacheParams::fully_associative(4096, 4));
    b.bench_items("cache_sim/fully_assoc_seq_100k", n as f64, || {
        for a in 0..n {
            sim4.access(a % 100_000);
        }
    });
}
