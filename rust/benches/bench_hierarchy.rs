//! Hierarchy bench: the same star13 analysis under natural vs
//! cache-fitting traversals on the single-level `r10000` machine vs the
//! full `r10000-full` (L1 + L2 + TLB) machine — §Perf tracks how much the
//! deeper model costs per simulated access and what the fitting order
//! saves at each level.

use stencilcache::cache::{Level, MachineModel};
use stencilcache::engine;
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{self, Traversal};
use stencilcache::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let grid = GridDesc::new(&[64, 64, 48]);
    let stencil = Stencil::star13();
    let accesses = grid.interior_points(2) as f64 * 14.0;

    for machine in [MachineModel::r10000(), MachineModel::r10000_full()] {
        let layout = MultiArrayLayout::paper_offsets(&grid, 1, machine.l1.size_words());
        let orders: [(&str, Box<dyn Traversal>); 2] = [
            ("natural", Box::new(traversal::natural_stream(&grid, 2))),
            ("fitting", Box::new(traversal::cache_fitting_stream_for_cache(&grid, 2, &machine.l1))),
        ];
        for (name, order) in &orders {
            let label = format!("hierarchy/{}/{name}_64x64x48", machine.name);
            let mut last_tlb = 0;
            b.bench_items(&label, accesses, || {
                let rep = engine::simulate_on_machine(order.as_ref(), &layout, &stencil, &machine);
                last_tlb = rep.levels.get(Level::Tlb).map(|s| s.misses()).unwrap_or(0);
            });
            if machine.is_hierarchical() {
                eprintln!("  ({label}: tlb misses {last_tlb})");
            }
        }
    }
}
