//! Serving-layer bench: cold vs memoized vs memoized-under-scan
//! throughput for the Zipf-over-hot-shapes workload the replay driver
//! models.
//!
//! - `cold`: memo tier disabled — every request pays the full lattice
//!   reduction + cache simulation.
//! - `memoized`: warm S3-FIFO tier — repeat requests cost an index probe.
//! - `memoized_under_scan`: the same hot traffic with a fresh (never
//!   cached) scan shape injected every iteration — measures that a
//!   one-pass sweep neither evicts the hot set nor drags hot throughput
//!   down (S3-FIFO's scan resistance on the serving path).

//!
//! Set STENCILCACHE_BENCH_JSON=<path> to write a machine-readable snapshot
//! (diffed against the committed BENCH_SERVING.json by CI's perf-smoke job);
//! STENCILCACHE_BENCH_PROVISIONAL=1 tags wall-clock entries report-only.

use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::experiments::replay;
use stencilcache::util::bench::{self, Bencher};
use stencilcache::util::json::Json;
use stencilcache::util::rng::Rng;
use std::cell::Cell;

fn main() {
    let mut b = Bencher::from_env();

    let hot = replay::hot_shapes(8);
    let mut rng = Rng::new(7);
    let wave: Vec<StencilRequest> = replay::zipf_requests(&hot, 1.1, 32, &mut rng);
    let n = wave.len() as f64;

    let mut cold = Coordinator::analysis_only(PlannerConfig::default());
    cold.configure_memo(None);
    b.bench_items("serving/cold_32_reqs", n, || cold.serve(&wave));

    // 64 KiB memo: the hot set fits with room to spare, but a scan
    // one-hit-wonder from ≳ 60 iterations back is long evicted *and* out
    // of the (resident-sized) ghost history — so the wrapped scan-shape
    // window below stays genuinely cold for any iteration count, instead
    // of silently warming once the shape family's 729-entry period wraps.
    let mut warm = Coordinator::analysis_only(PlannerConfig::default());
    warm.configure_memo(Some(64 * 1024));
    let _ = warm.serve(&wave); // prime the memo tier
    b.bench_items("serving/memoized_32_reqs", n, || warm.serve(&wave));

    // Each iteration appends one cold scan shape, so the memo tier keeps
    // absorbing one-hit-wonders while serving the hot wave.
    let scan_cursor = Cell::new(0usize);
    b.bench_items("serving/memoized_under_scan_32+1_reqs", n + 1.0, || {
        let i = scan_cursor.get();
        scan_cursor.set(i + 1);
        let mut reqs = wave.clone();
        // offset 100 keeps bench scan shapes clear of any replay-test use
        let dims = replay::scan_shapes(100 + (i % 600), 1).pop().unwrap();
        reqs.push(StencilRequest { dims, stencil: StencilSpec::Star13, rhs_arrays: 1, kind: JobKind::Analyze });
        warm.serve(&reqs)
    });

    if let Some(s) = warm.memo_snapshot() {
        println!(
            "memo tier after bench: {} entries, {}/{} bytes, hit rate {:.1}%, {} ghost readmits",
            s.entries,
            s.weight,
            s.capacity,
            100.0 * s.counters.hit_rate(),
            s.counters.ghost_readmits
        );
    }

    // Open-loop serving rows: deterministic Poisson / bursty arrival
    // schedules through the admission-controlled dispatch pipeline
    // (experiments::replay::run_open_loop). Sojourn tails are wall-clock,
    // so like every other wall-clock row they tag provisional only under
    // STENCILCACHE_BENCH_PROVISIONAL; the blessed committed rows gate at
    // perf-smoke's tolerance like the rest of the snapshot.
    let provisional = std::env::var("STENCILCACHE_BENCH_PROVISIONAL").is_ok();
    let mut extra = Vec::new();
    for arrivals in [replay::Arrivals::Poisson, replay::Arrivals::Bursty { burst: 32 }] {
        let cfg = replay::OpenLoopConfig { arrivals, ..replay::OpenLoopConfig::paper(true) };
        let out = replay::run_open_loop(&cfg);
        println!(
            "open_loop/{}: {}/{} completed, shed {:.1}%, p50 {:.3} ms, p99 {:.3} ms, p99.9 {:.3} ms, collapsed {}",
            out.label,
            out.completed,
            out.requests,
            100.0 * out.shed_rate(),
            out.p50_ms,
            out.p99_ms,
            out.p999_ms,
            out.collapsed
        );
        let mut o = Json::obj();
        o.set("name", format!("serving/open_loop_{}_2krps", out.label))
            .set("throughput_per_s", out.achieved_rps)
            .set("p50_ms", out.p50_ms)
            .set("p99_ms", out.p99_ms)
            .set("p999_ms", out.p999_ms)
            .set("shed_pct", 100.0 * out.shed_rate())
            .set("n", out.requests);
        if provisional {
            o.set("provisional", true);
        }
        extra.push(o);
    }

    if let Some(path) = bench::snapshot_path_from_env() {
        let snap = b.snapshot(provisional, extra);
        bench::write_snapshot(&path, &snap).expect("write bench snapshot");
        println!("wrote bench snapshot to {path}");
    }
}
