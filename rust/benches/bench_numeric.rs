//! Numeric-sweep bench: real stencil FLOPs on a 128³ star13 grid under
//! each traversal family — the wall-clock twin of the simulator's
//! miss-count comparison (paper §6 measured on the R10000; here measured
//! on whatever this machine is). Also times the sharded apply and the
//! coordinator's native solve path end-to-end.
//!
//! Set STENCILCACHE_BENCH_QUICK=1 for a smoke run.

use stencilcache::cache::CacheParams;
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::engine;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::solver;
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use stencilcache::util::bench::Bencher;
use stencilcache::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bencher::from_env();
    let n = 128usize;
    let grid = GridDesc::new(&[n, n, n]);
    let stencil = Stencil::star13();
    let cache = CacheParams::r10000();
    let r = stencil.radius();
    let points = grid.interior_points(r) as f64;

    let u = solver::deterministic_field(&grid, r, 1);
    let mut q = vec![0.0f64; grid.storage_words() as usize];

    // traversal families, sequential sweep: same FLOPs, different order —
    // the measured counterpart of the FIG4 miss comparison.
    let natural = traversal::natural_stream(&grid, r);
    b.bench_items(&format!("apply_{n}^3_star13/natural"), points, || {
        engine::apply(&natural, &grid, &stencil, &u, &mut q);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    let tiled = traversal::tiled_z_sweep_stream(&grid, r, cache.lattice_modulus(), 2);
    b.bench_items(&format!("apply_{n}^3_star13/tiled_z"), points, || {
        engine::apply(&tiled, &grid, &stencil, &u, &mut q);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    let lattice = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
    let fitting = traversal::cache_fitting_stream(&grid, r, &lattice);
    b.bench_items(&format!("apply_{n}^3_star13/cache_fitting"), points, || {
        engine::apply(&fitting, &grid, &stencil, &u, &mut q);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    // sharded apply: same natural order fanned out over the pool
    let pool = ThreadPool::with_default_parallelism();
    let shards = pool.workers() * 2;
    b.bench_items(&format!("apply_{n}^3_star13/natural_sharded_x{shards}"), points, || {
        engine::apply_sharded(&natural, &grid, &stencil, &u, &mut q, &pool, shards);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    // coordinator native solve end-to-end (plan → traversal → sharded
    // sweep → residual/L2 reductions), smaller grid to keep iterations sane
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let solve = StencilRequest {
        dims: vec![64, 64, 64],
        stencil: StencilSpec::Star13,
        rhs_arrays: 1,
        kind: JobKind::Solve { steps: 3 },
    };
    b.bench_items("coordinator/native_solve_64^3_x3steps", 3.0 * 64.0 * 64.0 * 64.0, || {
        coord.submit(&solve).unwrap()
    });
}
