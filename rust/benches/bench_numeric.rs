//! Numeric-sweep bench: real stencil FLOPs on a 128³ star13 grid under
//! each traversal family — the wall-clock twin of the simulator's
//! miss-count comparison (paper §6 measured on the R10000; here measured
//! on whatever this machine is). Also times the sharded apply and the
//! coordinator's native solve path end-to-end.
//!
//! Set STENCILCACHE_BENCH_QUICK=1 for a smoke run. Set
//! STENCILCACHE_BENCH_JSON=<path> to also write a machine-readable snapshot
//! (the file CI's perf-smoke job diffs against the committed
//! BENCH_NUMERIC.json); add STENCILCACHE_BENCH_PROVISIONAL=1 to tag the
//! wall-clock entries report-only for cross-machine baselines.

use stencilcache::cache::{CacheParams, MachineModel};
use stencilcache::coordinator::{
    choose_time_tile, temporal_solve_traffic_wpp, Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec,
    CLASSIC_SOLVE_TRAFFIC_WPP,
};
use stencilcache::engine;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::shard;
use stencilcache::solver::{self, NativeBackend, NumericBackend, NumericJob};
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use stencilcache::util::bench::{self, Bencher};
use stencilcache::util::json::Json;
use stencilcache::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bencher::from_env();
    let n = 128usize;
    let grid = GridDesc::new(&[n, n, n]);
    let stencil = Stencil::star13();
    let cache = CacheParams::r10000();
    let r = stencil.radius();
    let points = grid.interior_points(r) as f64;

    let u = solver::deterministic_field(&grid, r, 1);
    let mut q = vec![0.0f64; grid.storage_words() as usize];

    // traversal families, sequential sweep: same FLOPs, different order —
    // the measured counterpart of the FIG4 miss comparison.
    let natural = traversal::natural_stream(&grid, r);
    b.bench_items(&format!("apply_{n}^3_star13/natural"), points, || {
        engine::apply(&natural, &grid, &stencil, &u, &mut q);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    let tiled = traversal::tiled_z_sweep_stream(&grid, r, cache.lattice_modulus(), 2);
    b.bench_items(&format!("apply_{n}^3_star13/tiled_z"), points, || {
        engine::apply(&tiled, &grid, &stencil, &u, &mut q);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    let lattice = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
    let fitting = traversal::cache_fitting_stream(&grid, r, &lattice);
    b.bench_items(&format!("apply_{n}^3_star13/cache_fitting"), points, || {
        engine::apply(&fitting, &grid, &stencil, &u, &mut q);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    // Row-kernel rows (DESIGN.md §2.11): the retained per-point scalar
    // sweep (`apply_reference`, the bitwise reference) vs the row kernel
    // that all production paths now run. Without `--features simd` both
    // execute portable code and the rows measure the array-of-4 layout
    // alone; with it the rows dispatch to AVX2/FMA, and the third row
    // adds the planner's software-prefetch distance on top. The speedup
    // line printed below is the scalar-vs-SIMD acceptance number.
    let scalar_ns = b
        .bench_items(&format!("apply_{n}^3_star13/kernel_pointwise_scalar"), points, || {
            engine::apply_reference(&natural, &grid, &stencil, &u, &mut q);
            q[grid.offset_of(&[2, 2, 2]) as usize]
        })
        .median_ns();
    let rows_ns = b
        .bench_items(&format!("apply_{n}^3_star13/kernel_rows_default"), points, || {
            engine::apply(&natural, &grid, &stencil, &u, &mut q);
            q[grid.offset_of(&[2, 2, 2]) as usize]
        })
        .median_ns();
    let prefetch = MachineModel::modern().prefetch_distance();
    let rows_pf_cfg = engine::KernelCfg { strict: false, prefetch };
    let rows_pf_ns = b
        .bench_items(&format!("apply_{n}^3_star13/kernel_rows_prefetch{prefetch}"), points, || {
            engine::apply_cfg(&natural, &grid, &stencil, &u, &mut q, &rows_pf_cfg);
            q[grid.offset_of(&[2, 2, 2]) as usize]
        })
        .median_ns();
    println!(
        "kernel speedup vs pointwise scalar: rows {:.2}x, rows+prefetch({prefetch}) {:.2}x",
        scalar_ns / rows_ns,
        scalar_ns / rows_pf_ns
    );

    // sharded apply: same natural order fanned out over the pool
    let pool = ThreadPool::with_default_parallelism();
    let shards = pool.workers() * 2;
    b.bench_items(&format!("apply_{n}^3_star13/natural_sharded_x{shards}"), points, || {
        engine::apply_sharded(&natural, &grid, &stencil, &u, &mut q, &pool, shards);
        q[grid.offset_of(&[2, 2, 2]) as usize]
    });

    // coordinator native solve end-to-end (plan → traversal → sharded
    // sweep → residual/L2 reductions), smaller grid to keep iterations sane
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let solve = StencilRequest {
        dims: vec![64, 64, 64],
        stencil: StencilSpec::Star13,
        rhs_arrays: 1,
        kind: JobKind::Solve { steps: 3 },
    };
    b.bench_items("coordinator/native_solve_64^3_x3steps", 3.0 * 64.0 * 64.0 * 64.0, || {
        coord.submit(&solve).unwrap()
    });

    // Multi-step solve at the pinned 128³ size: the classic two-sweep loop
    // (apply into q, then axpy) vs the temporal path — fused k=1 (one pass
    // over memory per step, no q array) and the halo-deep depth the
    // r10000-full planner picks. Wall-clock face of the §6 temporal story.
    let steps = 5usize;
    let solve_items = steps as f64 * points;
    let backend = NativeBackend::new(&pool);
    let dims = [n, n, n];
    let job_classic = NumericJob {
        dims: &dims,
        grid: &grid,
        stencil: &stencil,
        traversal: &natural,
        shards,
        seed: 1,
        temporal: None,
    };
    b.bench_items(&format!("solve_{n}^3_star13_x{steps}/classic_single_step"), solve_items, || {
        backend.solve(&job_classic, steps).unwrap().result_norm
    });

    // fused k=1: whole interior, last dim split across shards (the tile the
    // coordinator builds when the planner degrades the depth to 1)
    let interior: Vec<usize> = grid.dims().iter().map(|&d| d.saturating_sub(2 * r).max(1)).collect();
    let mut fused_tile = interior.clone();
    let last = fused_tile.len() - 1;
    fused_tile[last] = fused_tile[last].div_ceil(shards.max(1));
    let fused = traversal::temporal_stream(&grid, r, &fused_tile, 1);
    let job_fused = NumericJob {
        dims: &dims,
        grid: &grid,
        stencil: &stencil,
        traversal: &natural,
        shards,
        seed: 1,
        temporal: Some(&fused),
    };
    b.bench_items(&format!("solve_{n}^3_star13_x{steps}/temporal_fused_k1"), solve_items, || {
        backend.solve(&job_fused, steps).unwrap().result_norm
    });

    // halo-deep depth from the r10000-full machine model (k=5 at 128³)
    let machine = MachineModel::preset("r10000-full").expect("known preset");
    let (k_deep, deep_tile) = choose_time_tile(&machine, &grid, r);
    assert!(k_deep > 1, "r10000-full must pick a halo-deep tile at 128^3");
    let deep = traversal::temporal_stream(&grid, r, &deep_tile, k_deep);
    let job_deep = NumericJob {
        dims: &dims,
        grid: &grid,
        stencil: &stencil,
        traversal: &natural,
        shards,
        seed: 1,
        temporal: Some(&deep),
    };
    b.bench_items(&format!("solve_{n}^3_star13_x{steps}/temporal_k{k_deep}_r10000full"), solve_items, || {
        backend.solve(&job_deep, steps).unwrap().result_norm
    });

    // Block-decomposed solve over the shard/halo layer (DESIGN.md §2.9):
    // the same explicit steps through per-shard blocks and typed HaloMsg
    // exchange — the wall-clock cost of the decomposition itself.
    let shard_grid = [2usize, 2, 2];
    let splan = std::sync::Arc::new(shard::ShardPlan::new(&dims, &shard_grid, r));
    let alpha = NativeBackend::stable_alpha(&stencil);
    let classic_shard_tp = b
        .bench_items(&format!("solve_{n}^3_star13_x{steps}/block_decomposed_2x2x2"), solve_items, || {
            shard::solve_blocks(&splan, &stencil, alpha, steps, 1, &shard::ShardStorage::InMemory, &pool, None)
                .unwrap()
                .final_norm
        })
        .throughput()
        .expect("items given");

    // Sharded temporal superstep (DESIGN.md §2.12): the same 2×2×2
    // decomposition with k-deep halos — shards exchange once per k steps
    // instead of every step, and each shard sweeps its slab k times while
    // it is cache-resident. Steps = k so the row measures exactly one
    // exchange round amortized over k sweeps.
    let k_shard = 4usize;
    let steps_k = k_shard;
    let deep_plan = std::sync::Arc::new(shard::ShardPlan::with_depth(&dims, &shard_grid, r, k_shard));
    let deep_tp = b
        .bench_items(
            &format!("solve_{n}^3_star13_x{steps_k}/sharded_temporal_k{k_shard}"),
            steps_k as f64 * points,
            || {
                shard::solve_blocks(&deep_plan, &stencil, alpha, steps_k, 1, &shard::ShardStorage::InMemory, &pool, None)
                    .unwrap()
                    .final_norm
            },
        )
        .throughput()
        .expect("items given");
    println!("sharded temporal k={k_shard} vs classic sharded: {:.2}x throughput", deep_tp / classic_shard_tp);
    // CI's perf-smoke job sets STENCILCACHE_BENCH_ENFORCE_RATIO so the
    // superstep path must clear the classic sharded row by 1.3x there;
    // local runs just print the ratio (wall-clock on unknown machines).
    // Even a same-run ratio can flake under noisy-neighbor scheduling on
    // a small shared runner, so a first miss gets one clean retry — both
    // rows re-timed back-to-back, best of three runs each — and only a
    // second miss fails the job.
    if std::env::var("STENCILCACHE_BENCH_ENFORCE_RATIO").is_ok() && deep_tp < 1.3 * classic_shard_tp {
        let best_tp = |steps: usize, plan: &std::sync::Arc<shard::ShardPlan>| -> f64 {
            (0..3)
                .map(|_| {
                    let t0 = std::time::Instant::now();
                    shard::solve_blocks(plan, &stencil, alpha, steps, 1, &shard::ShardStorage::InMemory, &pool, None)
                        .unwrap();
                    steps as f64 * points / t0.elapsed().as_secs_f64()
                })
                .fold(0.0f64, f64::max)
        };
        let classic_retry = best_tp(steps, &splan);
        let deep_retry = best_tp(steps_k, &deep_plan);
        println!(
            "ratio gate retry: classic {classic_retry:.3e}/s, sharded temporal {deep_retry:.3e}/s ({:.2}x)",
            deep_retry / classic_retry
        );
        assert!(
            deep_retry >= 1.3 * classic_retry,
            "sharded_temporal_k{k_shard} missed the 1.3x ratio gate twice: \
             first {:.2}x ({deep_tp:.3e}/s vs {classic_shard_tp:.3e}/s), retry {:.2}x",
            deep_tp / classic_shard_tp,
            deep_retry / classic_retry
        );
    }

    // Deterministic traffic-model entries (words moved between cache and
    // memory per point per step). Machine-independent by construction —
    // canonical tiles, not the shard-split ones — so CI hard-gates them:
    // any increase is a planner/model regression, never noise.
    let wpp_fused = temporal_solve_traffic_wpp(&grid, r, 1, &interior);
    let wpp_deep = temporal_solve_traffic_wpp(&grid, r, k_deep, &deep_tile);
    let model_entry = |name: String, wpp: f64| {
        let mut o = Json::obj();
        o.set("name", name).set("words_per_point", wpp);
        o
    };
    let mut extra = vec![
        model_entry(format!("model/solve_traffic_wpp_{n}^3_star13/classic"), CLASSIC_SOLVE_TRAFFIC_WPP),
        model_entry(format!("model/solve_traffic_wpp_{n}^3_star13/temporal_fused_k1"), wpp_fused),
        model_entry(format!("model/solve_traffic_wpp_{n}^3_star13/temporal_k{k_deep}_r10000full"), wpp_deep),
    ];
    // Geometric halo accounting of the 2×2×2 decomposition: exact,
    // machine-independent, hard-gated — a drift means the shard geometry
    // or the PEM bound changed, never noise.
    // Words the row kernel touches per interior point (13 operand loads
    // plus the one store) — exact by construction, so hard-gated: an
    // increase means the kernel started touching more memory per point.
    extra.push(model_entry(
        format!("model/kernel_touched_wpp_{n}^3_star13"),
        (stencil.size() + 1) as f64,
    ));
    let g = format!("{}x{}x{}", shard_grid[0], shard_grid[1], shard_grid[2]);
    extra.push(model_entry(format!("model/halo_wpp_{n}^3_star13_grid{g}"), splan.halo_words_per_point()));
    extra.push(model_entry(format!("model/halo_bound_wpp_{n}^3_star13_grid{g}"), splan.pem_halo_bound_per_point()));
    // Exchange-round accounting of the superstep path, measured from the
    // solve outcome rather than the model: a k-deep plan must load exactly
    // ⌈steps/k⌉ full-depth halo rounds. Hard-gated — an increase means the
    // superstep loop started exchanging more often than once per k steps.
    let deep_out = shard::solve_blocks(&deep_plan, &stencil, alpha, steps_k, 1, &shard::ShardStorage::InMemory, &pool, None)
        .expect("sharded temporal solve");
    let rounds = deep_out.halo_words_loaded as f64 / deep_plan.halo_words() as f64;
    assert_eq!(
        rounds,
        steps_k.div_ceil(k_shard) as f64,
        "k-deep superstep must exchange exactly ceil(steps/k) full-depth rounds"
    );
    extra.push(model_entry(
        format!("model/halo_rounds_per_step_{n}^3_star13_grid{g}_k{k_shard}"),
        rounds / steps_k as f64,
    ));
    println!(
        "sharded temporal exchange rounds: {rounds:.0} for {steps_k} steps at k={k_shard} ({:.3} rounds/step); \
         redundant ghost recompute {} words",
        rounds / steps_k as f64,
        deep_out.halo_redundant_words
    );
    println!(
        "modelled solve traffic (words/pt/step): classic {CLASSIC_SOLVE_TRAFFIC_WPP:.3}, \
         fused k=1 {wpp_fused:.3}, k={k_deep} halo-deep {wpp_deep:.3}"
    );
    println!(
        "halo traffic (words/pt/exchange, grid {g}): measured {:.6}, PEM bound {:.6}",
        splan.halo_words_per_point(),
        splan.pem_halo_bound_per_point()
    );

    if let Some(path) = bench::snapshot_path_from_env() {
        let provisional = std::env::var("STENCILCACHE_BENCH_PROVISIONAL").is_ok();
        let snap = b.snapshot(provisional, extra);
        bench::write_snapshot(&path, &snap).expect("write bench snapshot");
        println!("wrote bench snapshot to {path}");
    }
}
