//! FIG5 regeneration bench: one (n1, n2) cell of the Plot-A sweep plus the
//! pure-lattice Plot-B classification over the full 60×60 region (the
//! latter is number theory only and must stay trivially cheap).

use stencilcache::cache::CacheParams;
use stencilcache::experiments::{measure, OrderKind};
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::stencil::Stencil;
use stencilcache::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let stencil = Stencil::star13();
    let cache = CacheParams::r10000();

    let grid = GridDesc::new(&[70, 70, 10]);
    let accesses = grid.interior_points(2) as f64 * 14.0;
    b.bench_items("fig5a/one_cell_70x70x10", accesses, || {
        measure(&grid, &stencil, cache, OrderKind::Natural, 1)
    });

    b.bench_items("fig5b/full_60x60_classification", 3600.0, || {
        let mut short = 0usize;
        for n1 in 40..100usize {
            for n2 in 40..100usize {
                let lat = InterferenceLattice::new(&[n1, n2, 50], 4096);
                if lat.min_l1(7).is_some() {
                    short += 1;
                }
            }
        }
        short
    });
}
