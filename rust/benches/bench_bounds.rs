//! Bounds-machinery bench: octahedron combinatorics, Eq 7/12 evaluation,
//! LLL reduction and the Appendix-B construction across cache sizes.

use stencilcache::bounds;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::lll_reduce;
use stencilcache::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();

    b.bench("octahedron/volume(5, 1e6)", || bounds::octahedron_volume(5, 1_000_000));
    b.bench("octahedron/radius_for_surface(3, 8dS)", || bounds::radius_for_surface(3, 24 * 4096));

    let g = GridDesc::new(&[400, 400, 400]);
    b.bench("bounds/eq7_lower", || bounds::lower_bound_loads(&g, 4096));
    b.bench("bounds/eq12_upper", || bounds::upper_bound_loads(&g, 4096, 2, 3.0));

    b.bench("lll/reduce_3d_interference_basis", || {
        let mut basis = vec![vec![4096i64, 0, 0], vec![-91, 1, 0], vec![-9100, 0, 1]];
        lll_reduce(&mut basis);
        basis
    });

    for log_s in [10u32, 14, 18] {
        let s = 1usize << log_s;
        b.bench(&format!("appb/construct_favorable_3d_S=2^{log_s}"), || bounds::favorable::construct(3, s));
    }
}
