//! Bench: traversal-order generation (the planner-side cost of the cache
//! fitting algorithm), the sweep-vector / candidate ablation, and the
//! streaming-vs-materialized engine comparison on 128³ (the streaming path
//! must be no slower — it skips the packed-order allocation entirely).

use stencilcache::cache::{CacheParams, CacheSim};
use stencilcache::engine;
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::lattice::InterferenceLattice;
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{self, FittingOptions};
use stencilcache::tuner;
use stencilcache::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let grid = GridDesc::new(&[64, 91, 40]);
    let pts = grid.interior_points(2) as f64;
    let cache = CacheParams::r10000();
    let lat = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());

    b.bench_items("order/natural_64x91x40", pts, || traversal::natural(&grid, 2));
    b.bench_items("order/blocked_16^3", pts, || traversal::blocked(&grid, 2, &[16, 16, 16]));
    b.bench_items("order/pencil_fitting", pts, || traversal::cache_fitting(&grid, 2, &lat));
    b.bench_items("order/pencil_raster", pts, || {
        traversal::fitting::cache_fitting_opts(
            &grid,
            2,
            &lat,
            &FittingOptions { serpentine: false, ..FittingOptions::default() },
        )
    });
    b.bench_items("order/tiled_z", pts, || traversal::tiled::tiled_z_sweep(&grid, 2, 4096));

    // streaming constructors are O(pencils), not O(points): planning cost
    b.bench_items("stream/fitting_construct", pts, || traversal::cache_fitting_stream(&grid, 2, &lat));

    // lattice machinery (per-grid planning costs)
    b.bench("lattice/build+reduce", || InterferenceLattice::new(grid.storage_dims(), 4096));
    b.bench("lattice/shortest_vector", || lat.shortest());
    b.bench("lattice/min_l1(8)", || lat.min_l1(8));
    b.bench("tile/conflict_free_search", || traversal::conflict_free_tile(grid.storage_dims(), 4096, 2));

    // the full auto-tuner (calibration included)
    let stencil = Stencil::star13();
    b.bench("tuner/auto_fitting_order", || tuner::auto_fitting_order(&grid, &stencil, &cache));

    // --- streaming vs materialized, end to end on 128³ -------------------
    // Each iteration builds the order AND simulates it, so the materialized
    // entries pay their packed-Vec allocation + pack/unpack, the streaming
    // entries only the lazy generator. The natural pair replays the exact
    // same visit sequence; the fitting pair shares the pencil decomposition
    // and point multiset but may differ on within-pencil tie-breaks (f32
    // sweep rounding vs exact f64), so compare its two entries on wall
    // time, not miss-for-miss.
    let big = GridDesc::new(&[128, 128, 128]);
    let big_pts = big.interior_points(2) as f64;
    let accesses = big_pts * 14.0;
    let layout = MultiArrayLayout::paper_offsets(&big, 1, cache.size_words());
    let big_lat = InterferenceLattice::new(big.storage_dims(), cache.lattice_modulus());

    b.bench_items("e2e_128^3/natural_materialized", accesses, || {
        let order = traversal::natural(&big, 2);
        let mut sim = CacheSim::new(cache);
        engine::simulate(&order, &layout, &stencil, &mut sim)
    });
    b.bench_items("e2e_128^3/natural_streaming", accesses, || {
        let t = traversal::natural_stream(&big, 2);
        let mut sim = CacheSim::new(cache);
        engine::simulate(&t, &layout, &stencil, &mut sim)
    });
    b.bench_items("e2e_128^3/fitting_materialized", accesses, || {
        let order = traversal::cache_fitting(&big, 2, &big_lat);
        let mut sim = CacheSim::new(cache);
        engine::simulate(&order, &layout, &stencil, &mut sim)
    });
    b.bench_items("e2e_128^3/fitting_streaming", accesses, || {
        let t = traversal::cache_fitting_stream(&big, 2, &big_lat);
        let mut sim = CacheSim::new(cache);
        engine::simulate(&t, &layout, &stencil, &mut sim)
    });
}
