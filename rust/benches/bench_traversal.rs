//! Bench: traversal-order generation (the planner-side cost of the cache
//! fitting algorithm) plus the sweep-vector / candidate ablation.

use stencilcache::cache::CacheParams;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{self, FittingOptions};
use stencilcache::tuner;
use stencilcache::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let grid = GridDesc::new(&[64, 91, 40]);
    let pts = grid.interior_points(2) as f64;
    let cache = CacheParams::r10000();
    let lat = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());

    b.bench_items("order/natural_64x91x40", pts, || traversal::natural(&grid, 2));
    b.bench_items("order/blocked_16^3", pts, || traversal::blocked(&grid, 2, &[16, 16, 16]));
    b.bench_items("order/pencil_fitting", pts, || traversal::cache_fitting(&grid, 2, &lat));
    b.bench_items("order/pencil_raster", pts, || {
        traversal::fitting::cache_fitting_opts(
            &grid,
            2,
            &lat,
            &FittingOptions { serpentine: false, ..FittingOptions::default() },
        )
    });
    b.bench_items("order/tiled_z", pts, || traversal::tiled::tiled_z_sweep(&grid, 2, 4096));

    // lattice machinery (per-grid planning costs)
    b.bench("lattice/build+reduce", || InterferenceLattice::new(grid.storage_dims(), 4096));
    b.bench("lattice/shortest_vector", || lat.shortest());
    b.bench("lattice/min_l1(8)", || lat.min_l1(8));
    b.bench("tile/conflict_free_search", || traversal::conflict_free_tile(grid.storage_dims(), 4096, 2));

    // the full auto-tuner (calibration included)
    let stencil = Stencil::star13();
    b.bench("tuner/auto_fitting_order", || tuner::auto_fitting_order(&grid, &stencil, &cache));
}
