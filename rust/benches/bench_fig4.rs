//! FIG4 regeneration bench: one Figure-4 column (n1 = 67, favorable) and
//! one spike column (n1 = 45), natural vs auto-fitted, end to end through
//! order generation + simulation. `cargo bench --bench bench_fig4`.
//!
//! The full figure is `stencilcache experiment fig4`; this bench tracks the
//! per-column cost that dominates the sweep.

use stencilcache::cache::CacheParams;
use stencilcache::experiments::{measure, OrderKind};
use stencilcache::grid::GridDesc;
use stencilcache::stencil::Stencil;
use stencilcache::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    let stencil = Stencil::star13();
    let cache = CacheParams::r10000();
    let n3 = 20usize;

    for (label, n1) in [("favorable_n1=67", 67usize), ("spike_n1=45", 45)] {
        let grid = GridDesc::new(&[n1, 91, n3]);
        let pts = grid.interior_points(2) as f64;
        let accesses = pts * 14.0;
        b.bench_items(&format!("fig4/{label}/natural"), accesses, || {
            measure(&grid, &stencil, cache, OrderKind::Natural, 1)
        });
        b.bench_items(&format!("fig4/{label}/auto_fitting"), accesses, || {
            measure(&grid, &stencil, cache, OrderKind::Auto, 1)
        });
    }
}
